//! The compile-once execution API (DESIGN.md §8).
//!
//! The paper's node labeling and placement are a *static one-time* cost
//! ("a static one-time node labeling algorithm to sort nodes based on
//! criticality"), yet the pre-redesign entry points re-ran them on every
//! simulation. This module splits the pipeline the way the paper (and a
//! real toolflow) does:
//!
//! * [`crate::config::Overlay`] — the validated hardware description;
//! * [`Program`] — the one-time compile artifact: placed graph,
//!   criticality labels, per-PE BRAM images and the flag-word layout,
//!   produced by [`Program::compile`];
//! * [`Session`] — a cheap, resettable executor over a borrowed
//!   `Program`: pick a scheduler/backend variant, [`Session::run`], and
//!   repeat — placement and labeling are never redone.
//!
//! [`run_batch`] fans a set of scheduler/backend variants across OS
//! threads, all borrowing the same compiled artifact. Sweeps
//! ([`crate::coordinator::fig1_sweep`]) and capacity scans
//! ([`Program::fits`]) compile each workload exactly once per overlay
//! shape — `tests/compile_once.rs` holds them to that via
//! [`crate::place::build_count`] / [`crate::criticality::labeling_count`],
//! and `benches/compile_amortization.rs` measures what the sharing buys.

mod tables;

pub use tables::{RuntimeTables, SeedEntry};

use crate::config::{Overlay, OverlayConfig};
use crate::engine::{self, BackendKind, SimBackend};
use crate::graph::DataflowGraph;
use crate::passes::{Diagnostic, NodeMap, PassCtx, PassManager, PassStat};
use crate::pe::BramConfig;
use crate::place::Placement;
use crate::sched::SchedulerKind;
use crate::sim::{SimError, SimStats};
use crate::telemetry::{self, Registry, Telemetry};
use crate::util::par::run_parallel;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Process-wide count of program compilations (see [`compile_count`]).
static COMPILES: AtomicU64 = AtomicU64::new(0);

/// Number of [`Program::compile`] calls since process start. Monotonic
/// and process-global: compare *deltas*, and only from a test that owns
/// the whole process.
pub fn compile_count() -> u64 {
    COMPILES.load(Ordering::Relaxed)
}

/// A failure of the one-time compile phase (the `CompileError` arm of
/// [`crate::error::Error`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CompileError {
    /// A PE's local subgraph exceeds its BRAM budget (only checked when
    /// the overlay sets `enforce_capacity`; the budget is the compile
    /// scheduler's [`BramConfig::graph_words`]).
    CapacityExceeded {
        pe: usize,
        words_needed: usize,
        words_available: usize,
    },
    /// The `verify` pass found error-severity defects — the carried
    /// diagnostics are exactly what `tdp check` would report. Never
    /// produced for builder-constructed graphs (the builder rejects the
    /// same defects at construction time); reachable through the raw
    /// loader ([`crate::graph::graph_from_json_raw`]) and hand-built
    /// node lists.
    InvalidGraph { diagnostics: Vec<Diagnostic> },
    /// A PE was assigned more nodes than the 13-bit packet local index
    /// can address — a placement no route table can encode, failed hard
    /// regardless of `enforce_capacity`.
    LocalIndexOverflow { pe: usize, nodes: usize, max: usize },
}

impl std::fmt::Display for CompileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CompileError::CapacityExceeded { pe, words_needed, words_available } => write!(
                f,
                "PE {pe} needs {words_needed} BRAM words, has {words_available}"
            ),
            CompileError::InvalidGraph { diagnostics } => {
                write!(f, "graph failed verification with {} error(s)", diagnostics.len())?;
                if let Some(first) = diagnostics.first() {
                    write!(f, "; first: {first}")?;
                }
                Ok(())
            }
            CompileError::LocalIndexOverflow { pe, nodes, max } => write!(
                f,
                "PE {pe} holds {nodes} nodes but the 13-bit packet local index \
                 addresses only {max}"
            ),
        }
    }
}

impl std::error::Error for CompileError {}

/// Compile-time failures map onto the simulator's error surface so the
/// deprecated one-shot shims keep their exact pre-redesign errors:
/// capacity failures carry identical fields, a local-index overflow is
/// a capacity failure denominated in nodes, and verification failures
/// (unreachable through the shims, whose graphs are builder-validated)
/// collapse to an error count.
impl From<CompileError> for SimError {
    fn from(e: CompileError) -> Self {
        match e {
            CompileError::CapacityExceeded { pe, words_needed, words_available } => {
                SimError::CapacityExceeded { pe, words_needed, words_available }
            }
            CompileError::LocalIndexOverflow { pe, nodes, max } => SimError::CapacityExceeded {
                pe,
                words_needed: nodes,
                words_available: max,
            },
            CompileError::InvalidGraph { diagnostics } => {
                SimError::InvalidProgram { errors: diagnostics.len() }
            }
        }
    }
}

/// The compiled BRAM image summary of one PE: what its local subgraph
/// costs in graph-memory words (§II-B encoding: 2 words per node, 1 per
/// fanout edge).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeImage {
    /// nodes resident in this PE's graph memory
    pub nodes: usize,
    /// fanout edges stored alongside them
    pub edges: usize,
    /// total graph-memory words ([`BramConfig::words_used`])
    pub graph_words: usize,
}

/// One PE's BRAM overflow, itemized: the answer to "*which* PE failed
/// [`Program::fits`], and by how much" (see [`Program::fit_violations`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FitViolation {
    pub pe: usize,
    /// nodes resident on the PE
    pub nodes: usize,
    /// fanout edges stored alongside them
    pub edges: usize,
    /// words the image needs ([`BramConfig::words_used`])
    pub graph_words: usize,
    /// words the queried scheduler's budget provides
    pub budget: usize,
    /// `graph_words - budget`
    pub words_over: usize,
    /// the overflow in nodes, at this PE's average words/node — "move
    /// about this many nodes elsewhere and it fits"
    pub nodes_over: usize,
}

/// The flag-word layout of the out-of-order scheduler's RDY/PEND bit
/// vectors (§II-B: flags packed `flag_bits_used` per word, two vectors
/// per BRAM) — fixed at compile time by the BRAM geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlagLayout {
    /// flag bits packed per BRAM word ("for simpler arithmetic" the
    /// paper uses 32 of the 40)
    pub bits_per_word: usize,
    /// RDY + PEND flag words per BRAM
    pub words_per_bram: usize,
    /// total flag words per PE ([`BramConfig::flag_words`])
    pub words_per_pe: usize,
}

impl FlagLayout {
    fn of(bram: &BramConfig) -> Self {
        Self {
            bits_per_word: bram.flag_bits_used,
            words_per_bram: 2 * bram.words_per_bram.div_ceil(bram.flag_bits_used),
            words_per_pe: bram.flag_words(),
        }
    }
}

/// The shared compile outputs — placement, criticality labels, per-PE
/// BRAM images, the flag layout and the baked runtime tables — in one
/// `Arc`-shared allocation, so both the borrowing [`Program`] view and
/// the owned [`SharedProgram`] cache entry hand out the same artifact
/// without copying.
#[derive(Debug)]
struct Artifact {
    place: Arc<Placement>,
    criticality: Vec<u32>,
    pe_images: Vec<PeImage>,
    flags: FlagLayout,
    /// the flattened hot-path image every session's simulator consumes
    /// (DESIGN.md §10) — baked here, once, never at run time
    tables: Arc<RuntimeTables>,
    /// the transform result when an optimizing pipeline rewrote the
    /// graph (`None` for the default pipeline: sessions execute the
    /// borrowed original)
    exec: Option<Arc<DataflowGraph>>,
    /// accumulated original→compiled id map (`None` when `exec` is)
    map: Option<NodeMap>,
    /// warning-severity findings the pass pipeline attached
    diagnostics: Vec<Diagnostic>,
    /// per-pass timing + detail, in pipeline order (`--dump-passes`)
    pass_stats: Vec<PassStat>,
}

/// The one compile implementation behind [`Program::compile`] and
/// [`SharedProgram::compile`] (and the only place [`compile_count`]
/// increments): run the standard pass pipeline
/// ([`PassManager::standard`]) over a fresh [`PassCtx`] and tear the
/// context into the artifact. With a telemetry registry attached, each
/// pass runs inside a timed span on the `"compile"` track (DESIGN.md
/// §11); with `None` the instrumentation is a no-op closure call.
fn compile_artifact(
    g: &DataflowGraph,
    overlay: &Overlay,
    tel: Telemetry<'_>,
) -> Result<Artifact, CompileError> {
    COMPILES.fetch_add(1, Ordering::Relaxed);
    telemetry::count(tel, "compile.programs", 1);
    let cfg = *overlay.config();
    let mut cx = PassCtx::new(g, cfg);
    PassManager::standard(&cfg).run(&mut cx, tel)?;
    let (exec, map, place, crit, pe_images, tables, diagnostics, pass_stats) = cx.into_parts();
    Ok(Artifact {
        place: Arc::new(place.expect("standard pipeline places")),
        criticality: crit.expect("standard pipeline labels criticality"),
        pe_images: pe_images.expect("standard pipeline summarizes BRAM images"),
        flags: FlagLayout::of(&cfg.bram),
        tables: tables.expect("standard pipeline bakes tables"),
        exec,
        map,
        diagnostics,
        pass_stats,
    })
}

/// The one-time compile artifact: a graph placed and labeled for one
/// overlay shape. Immutable once built; any number of [`Session`]s can
/// borrow it (concurrently — it is `Sync`) and run scheduler/backend
/// variants without re-placing or re-labeling. Cloning is cheap (the
/// artifact is `Arc`-shared). For an owned, lifetime-free handle (cache
/// entries, service workers) see [`SharedProgram`].
#[derive(Clone)]
pub struct Program<'g> {
    g: &'g DataflowGraph,
    overlay: Overlay,
    art: Arc<Artifact>,
}

impl<'g> Program<'g> {
    /// Compile `g` for `overlay` by running the standard pass pipeline
    /// ([`PassManager::standard`]): verify, optional transforms (`opt`
    /// overlays), criticality labeling (one reverse topological sweep),
    /// placement (criticality-sorted local layouts), BRAM image
    /// summaries and the runtime-table bake. This is the entire
    /// one-time cost — every [`Session`] run afterwards starts from
    /// here for free.
    pub fn compile(g: &'g DataflowGraph, overlay: &Overlay) -> Result<Self, CompileError> {
        Self::compile_with(g, overlay, None)
    }

    /// [`Program::compile`] with a telemetry registry attached: each
    /// pass (verify, criticality, place, bram_images, bake_tables, plus
    /// the transforms on `opt` overlays) runs inside a timed span on
    /// the `"compile"` track.
    pub fn compile_with(
        g: &'g DataflowGraph,
        overlay: &Overlay,
        tel: Telemetry<'_>,
    ) -> Result<Self, CompileError> {
        Ok(Self {
            g,
            overlay: *overlay,
            art: Arc::new(compile_artifact(g, overlay, tel)?),
        })
    }

    /// The compiled graph, as handed to [`Program::compile`] — the id
    /// domain of `values()`, traces and stats.
    pub fn graph(&self) -> &'g DataflowGraph {
        self.g
    }

    /// The graph the artifact actually *executes*: the transform
    /// pipeline's rewrite when one ran (`opt` overlays), else the
    /// original. Placement, criticality and PE images are all in this
    /// graph's id domain; the baked tables remap the external surface
    /// back to [`Program::graph`] order.
    pub fn exec_graph(&self) -> &DataflowGraph {
        self.art.exec.as_deref().unwrap_or(self.g)
    }

    /// The original→compiled id map recorded by the transform passes
    /// (`None` when no transform changed the graph).
    pub fn node_map(&self) -> Option<&NodeMap> {
        self.art.map.as_ref()
    }

    /// Warning-severity diagnostics the pass pipeline attached at
    /// compile time (capacity pressure, dead inputs, fanout hotspots).
    /// Error-severity findings never reach here — they fail the compile
    /// as [`CompileError::InvalidGraph`].
    pub fn diagnostics(&self) -> &[Diagnostic] {
        &self.art.diagnostics
    }

    /// Per-pass wall-clock timing and detail lines, in pipeline order —
    /// the data behind `tdp run/perf --dump-passes`.
    pub fn pass_stats(&self) -> &[PassStat] {
        &self.art.pass_stats
    }

    /// The overlay this program was compiled for.
    pub fn overlay(&self) -> &Overlay {
        &self.overlay
    }

    /// The node→PE placement and per-PE memory layouts (in
    /// [`Program::exec_graph`] id domain).
    pub fn placement(&self) -> &Placement {
        &self.art.place
    }

    /// The shared placement handle — for custom engine drivers and
    /// ablation hooks (e.g. `Simulator::with_scheduler_factory_shared`).
    /// Note that paths taking a placement re-bake the runtime tables;
    /// [`Session`]s run off [`Program::runtime_tables`] directly and
    /// skip even that.
    pub fn shared_placement(&self) -> Arc<Placement> {
        Arc::clone(&self.art.place)
    }

    /// The baked runtime tables (DESIGN.md §10): the flattened,
    /// PE-major hot-path image — CSR route table of pre-formed packet
    /// headers, dense node metadata, global↔dense permutation — that
    /// every [`Session`]'s simulator consumes directly.
    pub fn runtime_tables(&self) -> Arc<RuntimeTables> {
        Arc::clone(&self.art.tables)
    }

    /// Per-node criticality labels (§II-B: height to the farthest
    /// sink), indexed by [`Program::exec_graph`] node id.
    pub fn criticality(&self) -> &[u32] {
        &self.art.criticality
    }

    /// Per-PE BRAM image summaries (of the executed graph).
    pub fn pe_images(&self) -> &[PeImage] {
        &self.art.pe_images
    }

    /// The out-of-order scheduler's flag-word layout.
    pub fn flag_layout(&self) -> FlagLayout {
        self.art.flags
    }

    /// Largest per-PE graph-memory footprint (words).
    pub fn max_graph_words(&self) -> usize {
        self.art.pe_images.iter().map(|i| i.graph_words).max().unwrap_or(0)
    }

    /// Does every PE's image fit `kind`'s BRAM budget? The capacity-scan
    /// query: one compile answers it for every scheduler. When this is
    /// `false`, [`Program::fit_violations`] names the offending PEs and
    /// quantifies each overflow.
    pub fn fits(&self, kind: SchedulerKind) -> bool {
        let budget = self.overlay.config().bram.graph_words(kind);
        self.max_graph_words() <= budget
    }

    /// Every PE whose image exceeds `kind`'s BRAM budget, with the
    /// overflow in words and approximate nodes — the explanation behind
    /// a `false` [`Program::fits`]. Empty exactly when the program fits.
    pub fn fit_violations(&self, kind: SchedulerKind) -> Vec<FitViolation> {
        let budget = self.overlay.config().bram.graph_words(kind);
        self.art
            .pe_images
            .iter()
            .enumerate()
            .filter(|(_, img)| img.graph_words > budget)
            .map(|(pe, img)| {
                let words_over = img.graph_words - budget;
                let words_per_node = (img.graph_words / img.nodes.max(1)).max(1);
                FitViolation {
                    pe,
                    nodes: img.nodes,
                    edges: img.edges,
                    graph_words: img.graph_words,
                    budget,
                    words_over,
                    nodes_over: words_over.div_ceil(words_per_node),
                }
            })
            .collect()
    }

    /// Smallest shard count at which this graph's total graph-memory
    /// footprint, split evenly across that many copies of this overlay,
    /// fits `kind`'s per-PE budget — the actionable number a failed
    /// [`Program::fits`] reports (`tdp check`, [`SimError::FitViolation`]
    /// paths). `1` when the program already fits. An *estimate*: boundary
    /// proxies add a little per-shard footprint and per-fabric placement
    /// imbalance can push a marginal shard over, so `tdp shard` verifies
    /// the actual partition.
    pub fn min_shards(&self, kind: SchedulerKind) -> usize {
        if self.fits(kind) {
            return 1;
        }
        let budget = self.overlay.config().bram.graph_words(kind);
        let per_fabric = budget * self.overlay.config().num_pes();
        if per_fabric == 0 {
            return usize::MAX;
        }
        let total: usize = self.art.pe_images.iter().map(|i| i.graph_words).sum();
        total.div_ceil(per_fabric).max(2)
    }

    /// Open a session at the overlay's default scheduler/backend.
    pub fn session(&self) -> Session<'_, 'g> {
        Session::new(self)
    }
}

/// An owned, lifetime-free compiled program: the graph is held by `Arc`,
/// so the artifact can live in long-lived caches and cross thread
/// boundaries — the entry type of the service layer's content-addressed
/// Program cache ([`crate::service::Engine`]). [`SharedProgram::program`]
/// reborrows it as a [`Program`] view for the [`Session`] API; both
/// handles share one artifact allocation.
#[derive(Clone)]
pub struct SharedProgram {
    graph: Arc<DataflowGraph>,
    overlay: Overlay,
    art: Arc<Artifact>,
}

impl SharedProgram {
    /// Compile `graph` for `overlay` — identical cost and result to
    /// [`Program::compile`] (same implementation, same
    /// [`compile_count`] accounting), but the result owns its graph.
    pub fn compile(graph: Arc<DataflowGraph>, overlay: &Overlay) -> Result<Self, CompileError> {
        Self::compile_with(graph, overlay, None)
    }

    /// [`SharedProgram::compile`] with a telemetry registry attached
    /// (see [`Program::compile_with`]).
    pub fn compile_with(
        graph: Arc<DataflowGraph>,
        overlay: &Overlay,
        tel: Telemetry<'_>,
    ) -> Result<Self, CompileError> {
        let art = Arc::new(compile_artifact(&graph, overlay, tel)?);
        Ok(Self { graph, overlay: *overlay, art })
    }

    /// The compiled graph.
    pub fn graph(&self) -> &Arc<DataflowGraph> {
        &self.graph
    }

    /// The overlay this program was compiled for.
    pub fn overlay(&self) -> &Overlay {
        &self.overlay
    }

    /// Borrow as a [`Program`] view (cheap: two `Arc` clones), from
    /// which sessions run: `shared.program().session().run()`.
    pub fn program(&self) -> Program<'_> {
        Program {
            g: &self.graph,
            overlay: self.overlay,
            art: Arc::clone(&self.art),
        }
    }
}

/// A cheap, resettable executor over a compiled [`Program`].
///
/// A session is a *plan*, not a running simulator: `with_*` pick the
/// variant, and every [`Session::run`] call builds a fresh simulator
/// over the shared placement — so repeated runs are independent (no
/// state leaks) and sessions can run concurrently from many threads.
#[derive(Clone, Copy)]
pub struct Session<'p, 'g> {
    program: &'p Program<'g>,
    cfg: OverlayConfig,
    telemetry: Telemetry<'p>,
    cancel: Option<&'p crate::sim::CancelToken>,
}

impl<'p, 'g> Session<'p, 'g> {
    /// A session at the program's overlay defaults.
    pub fn new(program: &'p Program<'g>) -> Self {
        Self {
            program,
            cfg: *program.overlay().config(),
            telemetry: None,
            cancel: None,
        }
    }

    /// Attach a cooperative cancellation / deadline token (DESIGN.md
    /// §15): the run polls it every
    /// [`crate::sim::CANCEL_CHECK_INTERVAL`] cycles and stops with a
    /// typed [`SimError::Cancelled`] / [`SimError::DeadlineExceeded`]
    /// carrying partial progress. Without this, nothing is polled.
    pub fn with_cancel(mut self, token: &'p crate::sim::CancelToken) -> Self {
        self.cancel = Some(token);
        self
    }

    /// Attach a telemetry registry: [`Session::run`] wraps backend
    /// construction and the run itself in timed spans on the `"run"`
    /// track and records the completion-cycle histogram. Without this
    /// the session carries `None` and pays nothing (DESIGN.md §11).
    pub fn with_telemetry(mut self, reg: &'p Registry) -> Self {
        self.telemetry = Some(reg);
        self
    }

    /// Run under `kind` instead of the overlay's default scheduler.
    pub fn with_scheduler(mut self, kind: SchedulerKind) -> Self {
        self.cfg.scheduler = kind;
        self
    }

    /// Run on `backend` instead of the overlay's default engine.
    pub fn with_backend(mut self, backend: BackendKind) -> Self {
        self.cfg.backend = backend;
        self
    }

    /// Override the cycle limit (livelock guard) for this session.
    pub fn with_max_cycles(mut self, max_cycles: u64) -> Self {
        self.cfg.max_cycles = max_cycles;
        self
    }

    /// The effective scheduler of this session.
    pub fn scheduler(&self) -> SchedulerKind {
        self.cfg.scheduler
    }

    /// The effective engine backend of this session.
    pub fn backend_kind(&self) -> BackendKind {
        self.cfg.backend
    }

    /// Construct (without running) the configured engine backend — for
    /// callers that need `values()` or incremental control afterwards.
    /// Runs straight off the compiled artifact's baked tables (over the
    /// program's [`Program::exec_graph`]): no placement, labeling or
    /// flattening work happens here. `values()` on the backend is in
    /// *original* graph order regardless of transforms — the tables
    /// carry the remap.
    pub fn backend(&self) -> Result<Box<dyn SimBackend + 'p>, SimError> {
        let mut backend = engine::backend_with_tables(
            self.program.exec_graph(),
            self.program.runtime_tables(),
            self.cfg,
        )?;
        if let Some(token) = self.cancel {
            backend.set_cancel(token.clone());
        }
        Ok(backend)
    }

    /// Run the compiled program to completion on this session's variant.
    pub fn run(&self) -> Result<SimStats, SimError> {
        let Some(reg) = self.telemetry else {
            // the disabled path is exactly the pre-telemetry code
            let mut backend = self.backend()?;
            return backend.run();
        };
        telemetry::count(self.telemetry, "run.sessions", 1);
        let mut backend = {
            let _setup = reg.span("run", "setup");
            self.backend()?
        };
        let result = {
            let _run = reg.span("run", self.cfg.scheduler.name());
            backend.run()
        };
        if let Ok(stats) = &result {
            telemetry::observe(self.telemetry, "run.cycles", stats.cycles);
        }
        result
    }
}

/// One scheduler/backend combination for [`run_batch`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunVariant {
    pub scheduler: SchedulerKind,
    pub backend: BackendKind,
}

impl RunVariant {
    /// Every scheduler × backend combination (scheduler-major; sized by
    /// [`BackendKind::ALL`], so new backends are picked up automatically).
    pub fn all() -> Vec<RunVariant> {
        [SchedulerKind::InOrder, SchedulerKind::OutOfOrder]
            .into_iter()
            .flat_map(|scheduler| {
                BackendKind::ALL.into_iter().map(move |backend| RunVariant { scheduler, backend })
            })
            .collect()
    }
}

/// Fan `variants` across `jobs` OS threads, every run borrowing the same
/// compiled `program` (placement and labels are shared, not recomputed —
/// the compile cost is paid exactly once for the whole batch). Results
/// come back in variant order.
pub fn run_batch(
    program: &Program<'_>,
    variants: &[RunVariant],
    jobs: usize,
) -> Vec<Result<SimStats, SimError>> {
    run_parallel(variants.to_vec(), jobs, |v: RunVariant| {
        program
            .session()
            .with_scheduler(v.scheduler)
            .with_backend(v.backend)
            .run()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::layered_random;

    fn overlay_2x2() -> Overlay {
        Overlay::builder().dims(2, 2).build().unwrap()
    }

    #[test]
    fn compile_then_run_matches_one_shot_simulator() {
        let g = layered_random(8, 4, 12, 2, 1);
        let overlay = overlay_2x2();
        let program = Program::compile(&g, &overlay).unwrap();
        let from_program = program.session().run().unwrap();
        let mut one_shot = crate::sim::Simulator::new(&g, *overlay.config()).unwrap();
        let direct = one_shot.run().unwrap();
        assert_eq!(from_program, direct);
    }

    #[test]
    fn program_exposes_compile_artifacts() {
        let g = layered_random(8, 4, 12, 2, 1);
        let overlay = overlay_2x2();
        let program = Program::compile(&g, &overlay).unwrap();
        assert_eq!(program.criticality().len(), g.len());
        assert_eq!(program.pe_images().len(), 4);
        let nodes: usize = program.pe_images().iter().map(|i| i.nodes).sum();
        let edges: usize = program.pe_images().iter().map(|i| i.edges).sum();
        assert_eq!(nodes, g.len());
        assert_eq!(edges, g.num_edges());
        for (pe, img) in program.pe_images().iter().enumerate() {
            assert_eq!(img.nodes, program.placement().nodes_of[pe].len());
            assert_eq!(img.graph_words, BramConfig::words_used(img.nodes, img.edges));
        }
        // paper geometry: 32 bits/word, 2*16 words/BRAM, 256 words/PE
        let flags = program.flag_layout();
        assert_eq!(flags.bits_per_word, 32);
        assert_eq!(flags.words_per_bram, 32);
        assert_eq!(flags.words_per_pe, 256);
    }

    /// The compiled artifact's baked tables agree with its placement —
    /// and sessions share one image allocation instead of re-flattening.
    #[test]
    fn artifact_bakes_runtime_tables_once() {
        let g = layered_random(8, 4, 12, 2, 1);
        let overlay = overlay_2x2();
        let program = Program::compile(&g, &overlay).unwrap();
        let t = program.runtime_tables();
        assert_eq!(t.len(), g.len());
        assert_eq!(t.routes.len(), g.num_edges());
        assert_eq!(t.num_pes, 4);
        assert_eq!((t.cols, t.rows), (2, 2));
        let place = program.placement();
        for global in 0..g.len() {
            let pe = place.pe_of[global] as usize;
            let local = place.local_of[global];
            assert_eq!(t.dense_of[global], t.pe_base[pe] + local);
            assert_eq!(t.global_of[t.dense_of[global] as usize] as usize, global);
        }
        assert_eq!(t.seeds.len(), g.num_inputs());
        // clones and repeated accessors share, not rebuild
        assert!(Arc::ptr_eq(&t, &program.clone().runtime_tables()));
    }

    #[test]
    fn sessions_are_independent_and_reconfigurable() {
        let g = layered_random(10, 5, 16, 2, 2);
        let overlay = overlay_2x2();
        let program = Program::compile(&g, &overlay).unwrap();
        let base = program.session().run().unwrap();
        for _ in 0..3 {
            assert_eq!(program.session().run().unwrap(), base, "no state leaks");
        }
        let in_order = program.session().with_scheduler(SchedulerKind::InOrder).run().unwrap();
        assert_eq!(in_order.scheduler, SchedulerKind::InOrder);
        let skip = program.session().with_backend(BackendKind::SkipAhead).run().unwrap();
        assert_eq!(skip, base, "backends are bit-exact over the same program");
    }

    #[test]
    fn session_max_cycles_override_fails_like_simulator() {
        let g = layered_random(8, 4, 8, 1, 0);
        let overlay = overlay_2x2();
        let program = Program::compile(&g, &overlay).unwrap();
        match program.session().with_max_cycles(3).run() {
            Err(SimError::CycleLimitExceeded { cycle, .. }) => assert_eq!(cycle, 3),
            other => panic!("expected cycle limit, got {other:?}"),
        }
    }

    #[test]
    fn compile_enforces_capacity() {
        let g = layered_random(64, 32, 128, 2, 0); // ~4K nodes on 1 PE
        let overlay = Overlay::builder().dims(1, 1).enforce_capacity(true).build().unwrap();
        match Program::compile(&g, &overlay) {
            Err(CompileError::CapacityExceeded { words_needed, words_available, .. }) => {
                assert!(words_needed > words_available);
            }
            Ok(_) => panic!("expected capacity error"),
        }
        assert!(!Program::compile(&g, &overlay_2x2()).unwrap().fits(SchedulerKind::InOrder));
    }

    #[test]
    fn shared_program_matches_borrowed_program() {
        let g = layered_random(10, 5, 16, 2, 2);
        let overlay = overlay_2x2();
        let borrowed = Program::compile(&g, &overlay).unwrap().session().run().unwrap();
        let shared = SharedProgram::compile(Arc::new(g), &overlay).unwrap();
        let owned = shared.program().session().run().unwrap();
        assert_eq!(owned, borrowed, "owned and borrowed compiles are bit-identical");
        // the view exposes the same artifact
        let view = shared.program();
        assert_eq!(view.criticality().len(), shared.graph().len());
        assert_eq!(view.pe_images().len(), 4);
        // clones share, not recompile: same placement allocation
        let clone = shared.clone();
        assert!(Arc::ptr_eq(
            &view.shared_placement(),
            &clone.program().shared_placement()
        ));
    }

    /// Telemetry contract (DESIGN.md §11): compiling with a registry
    /// records one span per compile stage, telemetered sessions wrap
    /// setup + run in spans — and none of it perturbs results.
    #[test]
    fn telemetry_records_compile_stages_and_run_spans() {
        let g = layered_random(8, 4, 12, 2, 1);
        let overlay = overlay_2x2();
        let reg = Registry::new();
        let program = Program::compile_with(&g, &overlay, Some(&reg)).unwrap();
        let stages: Vec<&str> = reg
            .spans()
            .iter()
            .filter(|s| s.track == "compile")
            .map(|s| s.name)
            .collect();
        assert_eq!(stages, ["verify", "criticality", "place", "bram_images", "bake_tables"]);
        assert_eq!(reg.counter("compile.programs"), 1);

        let plain = program.session().run().unwrap();
        let traced = program.session().with_telemetry(&reg).run().unwrap();
        assert_eq!(traced, plain, "telemetry must not perturb results");
        let runs: Vec<&str> = reg
            .spans()
            .iter()
            .filter(|s| s.track == "run")
            .map(|s| s.name)
            .collect();
        assert_eq!(runs, ["setup", plain.scheduler.name()]);
        assert_eq!(reg.counter("run.sessions"), 1);
        assert_eq!(reg.histogram("run.cycles").unwrap().count, 1);

        // the owned compile path threads telemetry identically
        let reg2 = Registry::new();
        SharedProgram::compile_with(Arc::new(g), &overlay, Some(&reg2)).unwrap();
        assert_eq!(reg2.spans().len(), 5);
    }

    #[test]
    fn fit_violations_name_the_overflowing_pes() {
        let g = layered_random(64, 32, 128, 2, 0);
        let program = Program::compile(&g, &overlay_2x2()).unwrap();
        assert!(!program.fits(SchedulerKind::InOrder));
        let v = program.fit_violations(SchedulerKind::InOrder);
        assert!(!v.is_empty(), "a failed fit is itemized");
        for f in &v {
            assert_eq!(f.words_over, f.graph_words - f.budget);
            assert!(f.nodes_over >= 1, "overflow expressed in nodes");
            assert_eq!(f.graph_words, BramConfig::words_used(f.nodes, f.edges));
        }
        // the larger OoO budget can only shrink the violation list
        assert!(program.fit_violations(SchedulerKind::OutOfOrder).len() <= v.len());
        let small = layered_random(8, 4, 12, 2, 1);
        let p2 = Program::compile(&small, &overlay_2x2()).unwrap();
        assert!(p2.fits(SchedulerKind::OutOfOrder));
        assert!(p2.fit_violations(SchedulerKind::OutOfOrder).is_empty());
    }

    #[test]
    fn invalid_graph_fails_compile_with_diagnostics() {
        let bad = r#"{"nodes":[{"in":1.0},{"op":"ADD","src":[2,0]},{"op":"MUL","src":[1,0]}]}"#;
        let g = crate::graph::graph_from_json_raw(bad).unwrap();
        match Program::compile(&g, &overlay_2x2()) {
            Err(CompileError::InvalidGraph { diagnostics }) => {
                assert!(diagnostics.iter().any(|d| d.code == "cycle"), "{diagnostics:?}");
                assert!(diagnostics
                    .iter()
                    .all(|d| d.severity == crate::passes::Severity::Error));
            }
            Err(other) => panic!("expected InvalidGraph, got {other:?}"),
            Ok(_) => panic!("cyclic graph must not compile"),
        }
    }

    #[test]
    fn default_pipeline_leaves_the_graph_alone_and_reports_passes() {
        let g = layered_random(8, 4, 12, 2, 1);
        let program = Program::compile(&g, &overlay_2x2()).unwrap();
        let names: Vec<_> = program.pass_stats().iter().map(|s| s.name).collect();
        assert_eq!(names, ["verify", "criticality", "place", "bram_images", "bake_tables"]);
        assert!(program.node_map().is_none(), "no transform on the default pipeline");
        assert_eq!(program.exec_graph().fingerprint(), g.fingerprint());
        assert_eq!(program.runtime_tables().values_len, g.len());
    }

    #[test]
    fn run_batch_covers_all_variants_in_order() {
        let g = layered_random(8, 4, 12, 2, 4);
        let overlay = overlay_2x2();
        let program = Program::compile(&g, &overlay).unwrap();
        let variants = RunVariant::all();
        let results = run_batch(&program, &variants, 3);
        assert_eq!(results.len(), variants.len());
        for (v, r) in variants.iter().zip(&results) {
            let stats = r.as_ref().unwrap();
            assert_eq!(stats.scheduler, v.scheduler, "results stay in variant order");
            assert_eq!(stats.completed, g.len());
        }
        // lockstep and skip-ahead agree per scheduler
        assert_eq!(results[0].as_ref().unwrap(), results[1].as_ref().unwrap());
        assert_eq!(results[2].as_ref().unwrap(), results[3].as_ref().unwrap());
    }
}
