//! Baked runtime tables — the compiled hot-path image of a placed graph
//! (DESIGN.md §10).
//!
//! The paper buys its cheap runtime with a one-time static pass: nodes
//! are labeled, sorted by criticality, and burned into per-PE BRAM
//! images the hardware then walks with plain address arithmetic. The
//! simulator exploits compile time the same way. [`RuntimeTables::build`]
//! flattens everything the per-cycle loop used to re-derive from the
//! object graph (`DataflowGraph` → `Node` → fanout → `Placement` lookups
//! → torus div/mod) into dense PE-major arrays:
//!
//! * a CSR **route table** whose entries are fully pre-formed [`Packet`]
//!   headers (dest x/y, destination local index, operand slot) — only
//!   the f32 payload is written at inject time, so building a fanout
//!   packet is a single indexed load;
//! * **node metadata** (opcode byte, arity, route CSR offsets, global
//!   id) indexed by *dense id* = `pe_base[pe] + local`, i.e. laid out in
//!   each PE's local-memory order (decreasing criticality under the
//!   paper's layout) so a PE's scheduler/packet-gen walk touches
//!   contiguous memory;
//! * the **global↔dense permutation**, kept so `values()` and trace
//!   output stay in graph node-id order while the inner loop never
//!   translates through `Placement` again;
//! * the **seed list** of graph inputs in node-id order — exactly the
//!   order the simulator has always marked inputs ready in, which
//!   in-order FIFOs observe.
//!
//! The tables are immutable once built and shared by `Arc`: a compiled
//! [`crate::program::Program`] bakes them once and every
//! [`crate::program::Session`] (or service job) reuses them;
//! constructing a [`crate::sim::Simulator`] directly builds a private
//! copy from its placement, bit-identically — `tests/artifact_tables.rs`
//! holds the two paths to stats-and-values equality.

use crate::config::OverlayConfig;
use crate::graph::{DataflowGraph, NodeKind, Op};
use crate::noc::{Packet, MAX_DIM, MAX_LOCAL_NODES};
use crate::place::Placement;
use crate::sim::SimError;
use std::sync::Arc;

/// One graph input's seeding record: where its initial token lives and
/// what to write there. Kept in graph node-id order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeedEntry {
    pub pe: u32,
    pub local: u32,
    pub dense: u32,
    pub global: u32,
    pub value: f32,
}

/// The flattened, PE-major runtime image of one (graph, placement,
/// overlay shape) — everything the simulator hot loop reads per cycle,
/// and nothing it doesn't. All fields are read-only after
/// [`RuntimeTables::build`]; consumers index them directly.
#[derive(Debug, Clone, PartialEq)]
pub struct RuntimeTables {
    pub num_pes: usize,
    /// torus width the route-table coordinates were baked for
    pub cols: usize,
    pub rows: usize,
    /// CSR over PEs: PE `p` owns dense ids `pe_base[p]..pe_base[p+1]`
    pub pe_base: Vec<u32>,
    /// per-PE torus coordinates `(x, y)` — precomputed once, replacing
    /// the per-packet `pe % cols` / `pe / cols` div-mod
    pub pe_xy: Vec<(u8, u8)>,
    /// dense-indexed opcode byte ([`Op::code8`]; [`Op::INPUT_CODE8`] for
    /// graph inputs)
    pub op: Vec<u8>,
    /// dense-indexed operand count (0 for inputs)
    pub arity: Vec<u8>,
    /// CSR over dense nodes: node `d`'s fanout packets are
    /// `routes[route_base[d]..route_base[d+1]]` (length `n + 1`)
    pub route_base: Vec<u32>,
    /// pre-formed packet headers in fanout-edge order; `payload` is 0.0
    /// until inject time
    pub routes: Vec<Packet>,
    /// dense id → graph node id (for `values()` mirroring / debug)
    pub global_of: Vec<u32>,
    /// graph node id → dense id (inverse permutation). In a remapped
    /// image ([`RuntimeTables::build_remapped`]) this is indexed by
    /// *original* ids: eliminated nodes hold `u32::MAX`, replicated
    /// nodes their first replica's dense id.
    pub dense_of: Vec<u32>,
    /// graph inputs in node-id order (the seed marking order)
    pub seeds: Vec<SeedEntry>,
    /// length of the external `values()` / trace domain — the original
    /// graph's node count. Equals [`RuntimeTables::len`] unless the
    /// tables were baked remapped over a transformed graph.
    pub values_len: usize,
}

impl RuntimeTables {
    /// Flatten `(g, place)` for a `cols`×`rows` torus. Pure and
    /// deterministic: the same inputs always bake identical tables, so
    /// a compile-time artifact and a constructor-built copy agree
    /// bit-for-bit.
    pub fn build(g: &DataflowGraph, place: &Placement, cols: usize, rows: usize) -> Self {
        assert_eq!(place.num_pes, cols * rows, "placement/torus shape mismatch");
        assert_eq!(place.pe_of.len(), g.len(), "placement covers the graph");
        let n = g.len();
        let layout = place.dense_layout();
        let pe_xy: Vec<(u8, u8)> = (0..place.num_pes)
            .map(|pe| ((pe % cols) as u8, (pe / cols) as u8))
            .collect();
        let mut op = Vec::with_capacity(n);
        let mut arity = Vec::with_capacity(n);
        let mut route_base = Vec::with_capacity(n + 1);
        let mut routes = Vec::with_capacity(g.num_edges());
        route_base.push(0u32);
        for &global in &layout.global_of {
            let node = g.node(global);
            op.push(match node.kind {
                NodeKind::Input { .. } => Op::INPUT_CODE8,
                NodeKind::Operation { op, .. } => op.code8(),
            });
            arity.push(node.arity() as u8);
            for &(dst, slot) in &node.fanout {
                let dpe = place.pe_of[dst as usize] as usize;
                let local = place.local_of[dst as usize];
                debug_assert!((local as usize) < MAX_LOCAL_NODES, "13 b local index");
                debug_assert!(dpe < MAX_DIM * MAX_DIM);
                routes.push(Packet {
                    dest_x: pe_xy[dpe].0,
                    dest_y: pe_xy[dpe].1,
                    local_idx: local as u16,
                    slot,
                    payload: 0.0,
                });
            }
            route_base.push(routes.len() as u32);
        }
        let seeds = g
            .nodes()
            .iter()
            .enumerate()
            .filter_map(|(global, node)| match node.kind {
                NodeKind::Input { value } => {
                    let dense = layout.dense_of[global];
                    Some(SeedEntry {
                        pe: place.pe_of[global],
                        local: place.local_of[global],
                        dense,
                        global: global as u32,
                        value,
                    })
                }
                NodeKind::Operation { .. } => None,
            })
            .collect();
        Self {
            num_pes: place.num_pes,
            cols,
            rows,
            pe_base: layout.pe_base,
            pe_xy,
            op,
            arity,
            route_base,
            routes,
            global_of: layout.global_of,
            dense_of: layout.dense_of,
            seeds,
            values_len: n,
        }
    }

    /// [`RuntimeTables::build`] over a *transformed* graph, with the
    /// external id surface remapped back to the original graph through
    /// `map` (the pass pipeline's accumulated original→compiled map).
    /// The hot-path arrays (`op`/`arity`/`routes`/`pe_base`) stay in
    /// the transformed graph's domain — that is what executes — but
    /// `global_of`, `dense_of`, `seeds[].global` and `values_len` speak
    /// original ids, so `values()` and traces keep original graph
    /// order. Replicas of one original all mirror into the same slot
    /// (they carry the same value by construction); eliminated
    /// originals keep `dense_of == u32::MAX` and a 0.0 value.
    pub fn build_remapped(
        g: &DataflowGraph,
        place: &Placement,
        cols: usize,
        rows: usize,
        map: &crate::passes::NodeMap,
    ) -> Self {
        debug_assert_eq!(map.orig_of.len(), g.len(), "map covers the transformed graph");
        let mut t = Self::build(g, place, cols, rows);
        t.values_len = map.orig_len;
        for s in &mut t.seeds {
            s.global = map.orig_of[s.global as usize];
        }
        for slot in &mut t.global_of {
            *slot = map.orig_of[*slot as usize];
        }
        let mut dense_of = vec![u32::MAX; map.orig_len];
        for (dense, &orig) in t.global_of.iter().enumerate() {
            let slot = &mut dense_of[orig as usize];
            *slot = (*slot).min(dense as u32);
        }
        t.dense_of = dense_of;
        t
    }

    /// [`RuntimeTables::build_remapped`] behind an `Arc`.
    pub fn build_remapped_shared(
        g: &DataflowGraph,
        place: &Placement,
        cols: usize,
        rows: usize,
        map: &crate::passes::NodeMap,
    ) -> Arc<Self> {
        Arc::new(Self::build_remapped(g, place, cols, rows, map))
    }

    /// [`RuntimeTables::build`] behind an `Arc` (the shape every
    /// consumer holds).
    pub fn build_shared(
        g: &DataflowGraph,
        place: &Placement,
        cols: usize,
        rows: usize,
    ) -> Arc<Self> {
        Arc::new(Self::build(g, place, cols, rows))
    }

    /// Total nodes in the image.
    #[inline]
    pub fn len(&self) -> usize {
        self.op.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.op.is_empty()
    }

    /// Nodes resident in `pe`'s local memory.
    #[inline]
    pub fn local_count(&self, pe: usize) -> usize {
        (self.pe_base[pe + 1] - self.pe_base[pe]) as usize
    }

    /// Dense id of `(pe, local)` — the one address computation of the
    /// hot loop.
    #[inline]
    pub fn dense(&self, pe: usize, local: u32) -> usize {
        (self.pe_base[pe] + local) as usize
    }

    /// Fanout edge count of dense node `d` (CSR span length).
    #[inline]
    pub fn route_len(&self, dense: usize) -> u32 {
        self.route_base[dense + 1] - self.route_base[dense]
    }

    /// The pre-formed packet for fanout `edge` of dense node `d`, with
    /// `payload` filled in: one indexed load plus a field write.
    #[inline]
    pub fn packet(&self, dense: usize, edge: u32, payload: f32) -> Packet {
        self.routes[(self.route_base[dense] + edge) as usize].with_payload(payload)
    }

    /// Per-PE `(nodes, fanout edges)` counts — the capacity-model view
    /// of the image (each PE's CSR spans, no graph access).
    pub fn pe_counts(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        (0..self.num_pes).map(|pe| {
            let lo = self.pe_base[pe] as usize;
            let hi = self.pe_base[pe + 1] as usize;
            let edges = (self.route_base[hi] - self.route_base[lo]) as usize;
            (hi - lo, edges)
        })
    }

    /// The per-PE BRAM budget check over the baked image — the same
    /// verdict (and error fields) as [`crate::sim::check_capacity`] on
    /// the placement it was built from, via the shared counts core.
    pub(crate) fn check_capacity(&self, cfg: &OverlayConfig) -> Result<(), SimError> {
        crate::sim::check_capacity_counts(self.pe_counts(), cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;
    use crate::place::{LocalOrder, PlacementPolicy};
    use crate::workload::layered_random;

    fn diamond() -> DataflowGraph {
        let mut g = DataflowGraph::new();
        let a = g.add_input(3.0);
        let b = g.add_input(4.0);
        let s = g.op(Op::Add, &[a, b]);
        let p = g.op(Op::Mul, &[a, b]);
        g.op(Op::Sub, &[s, p]);
        g
    }

    /// Hand-checked image of the diamond on a 2×2 round-robin placement
    /// with arrival-order local memory: every route entry, opcode and
    /// permutation slot pinned.
    #[test]
    fn diamond_tables_golden() {
        let g = diamond();
        // pe_of = [0, 1, 2, 3, 0]; coords: pe0=(0,0) pe1=(1,0) pe2=(0,1) pe3=(1,1)
        let place = Placement::build(&g, 4, PlacementPolicy::RoundRobin, LocalOrder::ByNodeId, 0);
        let t = RuntimeTables::build(&g, &place, 2, 2);
        assert_eq!(t.pe_base, vec![0, 2, 3, 4, 5]);
        assert_eq!(t.pe_xy, vec![(0, 0), (1, 0), (0, 1), (1, 1)]);
        // dense order: [n0, n4, n1, n2, n3]
        assert_eq!(t.global_of, vec![0, 4, 1, 2, 3]);
        assert_eq!(t.dense_of, vec![0, 2, 3, 4, 1]);
        let inp = Op::INPUT_CODE8;
        assert_eq!(t.op, vec![inp, Op::Sub.code8(), inp, Op::Add.code8(), Op::Mul.code8()]);
        assert_eq!(t.arity, vec![0, 2, 0, 2, 2]);
        // fanouts: n0→(2,0)(3,0), n4→(), n1→(2,1)(3,1), n2→(4,0), n3→(4,1)
        assert_eq!(t.route_base, vec![0, 2, 2, 4, 5, 6]);
        let hdr = |x: u8, y: u8, local: u16, slot: u8| Packet {
            dest_x: x,
            dest_y: y,
            local_idx: local,
            slot,
            payload: 0.0,
        };
        assert_eq!(
            t.routes,
            vec![
                hdr(0, 1, 0, 0), // n0 → n2 (pe2, local 0), slot 0
                hdr(1, 1, 0, 0), // n0 → n3 (pe3, local 0), slot 0
                hdr(0, 1, 0, 1), // n1 → n2, slot 1
                hdr(1, 1, 0, 1), // n1 → n3, slot 1
                hdr(0, 0, 1, 0), // n2 → n4 (pe0, local 1), slot 0
                hdr(0, 0, 1, 1), // n3 → n4, slot 1
            ]
        );
        // seeds in node-id order
        assert_eq!(t.seeds.len(), 2);
        assert_eq!((t.seeds[0].global, t.seeds[0].pe, t.seeds[0].local), (0, 0, 0));
        assert_eq!(t.seeds[0].value, 3.0);
        assert_eq!((t.seeds[1].global, t.seeds[1].pe, t.seeds[1].local), (1, 1, 0));
        assert_eq!(t.seeds[1].value, 4.0);
        // accessors agree with the raw arrays
        assert_eq!(t.local_count(0), 2);
        assert_eq!(t.route_len(t.dense(0, 0)), 2);
        assert_eq!(t.route_len(t.dense(0, 1)), 0, "n4 is a sink");
        let p = t.packet(t.dense(2, 0), 0, 7.5);
        assert_eq!(p, hdr(0, 0, 1, 0).with_payload(7.5));
    }

    /// Every route entry must agree with what the seed hot path derived
    /// per packet: fanout target → pe_of → local_of → div/mod coords.
    #[test]
    fn routes_match_graph_derivation() {
        let g = layered_random(12, 5, 20, 2, 11);
        let (cols, rows) = (3, 2);
        let order = LocalOrder::ByCriticality;
        let place = Placement::build(&g, cols * rows, PlacementPolicy::Chunked, order, 4);
        let t = RuntimeTables::build(&g, &place, cols, rows);
        assert_eq!(t.routes.len(), g.num_edges());
        for dense in 0..t.len() {
            let global = t.global_of[dense];
            let node = g.node(global);
            assert_eq!(t.route_len(dense) as usize, node.fanout.len());
            assert_eq!(t.arity[dense] as usize, node.arity());
            match node.op() {
                Some(op) => assert_eq!(t.op[dense], op.code8()),
                None => assert_eq!(t.op[dense], Op::INPUT_CODE8),
            }
            for (edge, &(dst, slot)) in node.fanout.iter().enumerate() {
                let p = t.packet(dense, edge as u32, 0.0);
                let dpe = place.pe_of[dst as usize] as usize;
                assert_eq!(p.dest_x as usize, dpe % cols);
                assert_eq!(p.dest_y as usize, dpe / cols);
                assert_eq!(p.local_idx as u32, place.local_of[dst as usize]);
                assert_eq!(p.slot, slot);
            }
        }
        // pe_counts is the capacity view of the same image
        let (nodes, edges): (Vec<_>, Vec<_>) = t.pe_counts().unzip();
        assert_eq!(nodes.iter().sum::<usize>(), g.len());
        assert_eq!(edges.iter().sum::<usize>(), g.num_edges());
        for (pe, locals) in place.nodes_of.iter().enumerate() {
            assert_eq!(nodes[pe], locals.len());
        }
    }

    /// A remapped bake keeps the external id surface in *original*
    /// graph order while the executable arrays stay compiled-domain.
    #[test]
    fn remapped_tables_speak_original_ids() {
        // original: diamond + a dead input at id 2; DCE drops it
        let mut g = DataflowGraph::new();
        let a = g.add_input(3.0);
        let b = g.add_input(4.0);
        let _dead = g.add_input(9.0);
        let s = g.op(Op::Add, &[a, b]);
        g.op(Op::Sub, &[s, s]);
        let (g2, map) = crate::passes::dce::run(&g).expect("one dead input");
        let place = Placement::build(&g2, 2, PlacementPolicy::RoundRobin, LocalOrder::ByNodeId, 0);
        let t = RuntimeTables::build_remapped(&g2, &place, 2, 1, &map);
        assert_eq!(t.len(), 4, "executable image is the compiled graph");
        assert_eq!(t.values_len, 5, "external domain is the original graph");
        // global_of names original ids (dead id 2 absent); dense_of is
        // total over originals with MAX for the eliminated node
        let mut named: Vec<u32> = t.global_of.clone();
        named.sort_unstable();
        assert_eq!(named, vec![0, 1, 3, 4]);
        assert_eq!(t.dense_of[2], u32::MAX);
        for orig in [0u32, 1, 3, 4] {
            assert_eq!(t.global_of[t.dense_of[orig as usize] as usize], orig);
        }
        // seeds carry original ids and original values
        let globals: Vec<u32> = t.seeds.iter().map(|s| s.global).collect();
        assert_eq!(globals, vec![0, 1]);
    }
}
