//! The compile pass pipeline (DESIGN.md §12).
//!
//! `Program::compile` used to be a fixed four-stage function; it is now
//! a [`PassManager`] running an ordered list of named passes over a
//! mutable [`PassCtx`] — the berkeley-emulation-engine compiler layout
//! (one small file per pass over a shared graph). Passes come in two
//! kinds:
//!
//! * **analysis passes** read the context and attach annotations
//!   ([`verify`] emits [`Diagnostic`]s, `criticality` attaches labels,
//!   `place` builds the [`crate::place::Placement`]);
//! * **transform passes** rewrite the graph ([`dce`],
//!   [`replicate_consts`]) and record a [`NodeMap`] so every id-indexed
//!   consumer downstream — `values()`, stats, traces — keeps *original*
//!   graph order.
//!
//! Contract highlights (full text in DESIGN.md §12):
//!
//! * the pipeline owns annotation flow: a pass reads what earlier
//!   passes wrote and never recomputes it (the compile-once counters in
//!   `tests/compile_once.rs` hold the standard pipeline to exactly one
//!   criticality labeling and one placement build per compile);
//! * every pass runs inside a timed telemetry span on the `"compile"`
//!   track plus a wall-clock [`PassStat`] surfaced by
//!   `tdp run/perf --dump-passes`;
//! * transforms compose their id remaps ([`NodeMap::then`]); the final
//!   map is threaded into the baked runtime tables
//!   ([`crate::program::RuntimeTables`]) so the executable image speaks
//!   compiled ids while its external surface speaks original ids.

pub mod dce;
pub mod partition;
pub mod replicate_consts;
pub mod verify;

use crate::config::OverlayConfig;
use crate::criticality;
use crate::graph::{DataflowGraph, NodeId};
use crate::noc::MAX_LOCAL_NODES;
use crate::pe::BramConfig;
use crate::place::{placement_cost, Placement, PlacementPolicy};
use crate::program::{CompileError, PeImage, RuntimeTables};
use crate::telemetry::{self, Telemetry};
use std::sync::Arc;
use std::time::Instant;

/// How bad a [`Diagnostic`] is. `Error` fails compilation (and gives
/// `tdp check` its non-zero exit); `Warning` is advisory and travels
/// with the artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One structured finding about a graph — the unit `tdp check` prints
/// (text or JSON) and [`CompileError::InvalidGraph`] carries. `code` is
/// a stable machine-readable slug (`"cycle"`, `"dangling-edge"`,
/// `"capacity"`, ...); `node` is the original-graph node it anchors to,
/// when there is one.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub severity: Severity,
    pub code: &'static str,
    pub node: Option<NodeId>,
    pub message: String,
}

impl Diagnostic {
    pub fn error(code: &'static str, node: Option<NodeId>, message: String) -> Self {
        Self { severity: Severity::Error, code, node, message }
    }

    pub fn warning(code: &'static str, node: Option<NodeId>, message: String) -> Self {
        Self { severity: Severity::Warning, code, node, message }
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}[{}]", self.severity.name(), self.code)?;
        if let Some(n) = self.node {
            write!(f, " node {n}")?;
        }
        write!(f, ": {}", self.message)
    }
}

/// Wall-clock timing and a one-line result summary of one executed
/// pass, kept on the compiled artifact for `--dump-passes`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PassStat {
    pub name: &'static str,
    pub micros: u64,
    /// pass-specific one-liner ("removed 3 dead inputs", "cost 812→540")
    pub detail: String,
}

/// A bijection-with-casualties between *original* graph node ids and
/// *compiled* (post-transform) ids. Dead original nodes map to
/// [`NodeMap::DEAD`]; replicated originals map to their first replica,
/// and every replica maps back to its original — so `orig_of` is total
/// over compiled ids while `compiled_of` is total over original ids.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeMap {
    /// node count of the original graph (the `values()` domain)
    pub orig_len: usize,
    /// original id → compiled id ([`NodeMap::DEAD`] when eliminated)
    pub compiled_of: Vec<u32>,
    /// compiled id → original id (replicas map to their original)
    pub orig_of: Vec<u32>,
}

impl NodeMap {
    /// Sentinel for an eliminated original node.
    pub const DEAD: u32 = u32::MAX;

    /// Compose: `self` (applied first) followed by `next`.
    pub fn then(&self, next: &NodeMap) -> NodeMap {
        debug_assert_eq!(self.orig_of.len(), next.orig_len);
        NodeMap {
            orig_len: self.orig_len,
            compiled_of: self
                .compiled_of
                .iter()
                .map(|&mid| {
                    if mid == Self::DEAD {
                        Self::DEAD
                    } else {
                        next.compiled_of[mid as usize]
                    }
                })
                .collect(),
            orig_of: next
                .orig_of
                .iter()
                .map(|&mid| self.orig_of[mid as usize])
                .collect(),
        }
    }

    /// Is original node `orig` still present in the compiled graph?
    pub fn is_live(&self, orig: NodeId) -> bool {
        self.compiled_of[orig as usize] != Self::DEAD
    }
}

/// The shared mutable state a pipeline threads through its passes: the
/// graph view (original, then transformed), node annotations, collected
/// warning diagnostics, and per-pass stats. Fields are public so custom
/// pipelines (and the `tdp check` driver) can pre-seed or harvest them;
/// the graph itself goes through [`PassCtx::graph`] /
/// [`PassCtx::commit_graph`] so the id remap can never silently detach
/// from the graph it describes.
pub struct PassCtx<'g> {
    /// the overlay knobs compilation targets
    pub cfg: OverlayConfig,
    orig: &'g DataflowGraph,
    owned: Option<Arc<DataflowGraph>>,
    map: Option<NodeMap>,
    /// criticality labels over the *current* graph (set by `criticality`)
    pub crit: Option<Vec<u32>>,
    /// node→PE placement (set by `place`)
    pub place: Option<Placement>,
    /// per-PE BRAM image summaries (set by `bram_images`)
    pub pe_images: Option<Vec<PeImage>>,
    /// the baked hot-path image (set by `bake_tables`)
    pub tables: Option<Arc<RuntimeTables>>,
    /// multi-fabric shard assignment (set by `partition`; sharded
    /// pipelines only — see [`crate::shard`])
    pub partition: Option<partition::Partition>,
    /// warning-severity findings accumulated across passes
    pub diags: Vec<Diagnostic>,
    /// one entry per executed pass, in pipeline order
    pub stats: Vec<PassStat>,
}

impl<'g> PassCtx<'g> {
    pub fn new(orig: &'g DataflowGraph, cfg: OverlayConfig) -> Self {
        Self {
            cfg,
            orig,
            owned: None,
            map: None,
            crit: None,
            place: None,
            pe_images: None,
            tables: None,
            partition: None,
            diags: Vec::new(),
            stats: Vec::new(),
        }
    }

    /// The current graph: the latest committed transform result, or the
    /// original when no transform has run.
    pub fn graph(&self) -> &DataflowGraph {
        self.owned.as_deref().unwrap_or(self.orig)
    }

    /// The original (pre-transform) graph.
    pub fn original(&self) -> &'g DataflowGraph {
        self.orig
    }

    /// Replace the current graph with a transform result, composing
    /// `step` (current → new ids) onto the accumulated original→compiled
    /// map. Annotations over the old graph (criticality, placement) are
    /// *not* remapped — the standard pipeline orders transforms before
    /// analyses, and a custom pipeline that violates that must re-run
    /// its analyses itself.
    pub fn commit_graph(&mut self, g: DataflowGraph, step: NodeMap) {
        debug_assert_eq!(step.orig_len, self.graph().len(), "step maps the current graph");
        debug_assert_eq!(step.orig_of.len(), g.len(), "step covers the new graph");
        self.map = Some(match &self.map {
            Some(prev) => prev.then(&step),
            None => step,
        });
        self.owned = Some(Arc::new(g));
    }

    /// The accumulated original→compiled id map (`None` when no
    /// transform changed the graph).
    pub fn node_map(&self) -> Option<&NodeMap> {
        self.map.as_ref()
    }

    /// Tear the context into the artifact parts the program layer
    /// stores: (exec graph if rewritten, id map, placement, criticality,
    /// pe images, tables, warnings, pass stats).
    #[allow(clippy::type_complexity)]
    pub(crate) fn into_parts(
        self,
    ) -> (
        Option<Arc<DataflowGraph>>,
        Option<NodeMap>,
        Option<Placement>,
        Option<Vec<u32>>,
        Option<Vec<PeImage>>,
        Option<Arc<RuntimeTables>>,
        Vec<Diagnostic>,
        Vec<PassStat>,
    ) {
        (
            self.owned, self.map, self.place, self.crit, self.pe_images, self.tables, self.diags,
            self.stats,
        )
    }
}

/// One named unit of compilation work. `run` returns a one-line detail
/// string for the pass report, or a [`CompileError`] that aborts the
/// pipeline.
pub trait Pass {
    fn name(&self) -> &'static str;
    fn run(&self, cx: &mut PassCtx<'_>, tel: Telemetry<'_>) -> Result<String, CompileError>;
}

/// An ordered pass list. [`PassManager::run`] executes each pass inside
/// a timed telemetry span on the `"compile"` track and records a
/// [`PassStat`] per pass into the context.
#[derive(Default)]
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
}

impl PassManager {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a pass (builder style).
    pub fn with(mut self, pass: impl Pass + 'static) -> Self {
        self.passes.push(Box::new(pass));
        self
    }

    /// Pipeline order, for reports and tests.
    pub fn names(&self) -> Vec<&'static str> {
        self.passes.iter().map(|p| p.name()).collect()
    }

    /// The standard compile pipeline for `cfg`:
    /// `verify → [dce → replicate_consts]* → criticality → place →
    /// bram_images → bake_tables` (`*` only when `cfg.opt` is set, so
    /// the default artifact is bit-identical to the pre-pipeline
    /// compiler).
    pub fn standard(cfg: &OverlayConfig) -> Self {
        let mut pm = Self::new().with(VerifyPass);
        if cfg.opt {
            pm = pm.with(DcePass).with(ReplicateConstsPass);
        }
        pm.with(CriticalityPass).with(PlacePass).with(BramImagesPass).with(BakeTablesPass)
    }

    /// Run every pass in order over `cx`. Stops at the first failing
    /// pass; stats for completed passes are retained either way.
    pub fn run(&self, cx: &mut PassCtx<'_>, tel: Telemetry<'_>) -> Result<(), CompileError> {
        for pass in &self.passes {
            let t0 = Instant::now();
            let detail = telemetry::timed(tel, "compile", pass.name(), || pass.run(cx, tel))?;
            cx.stats.push(PassStat {
                name: pass.name(),
                micros: t0.elapsed().as_micros() as u64,
                detail,
            });
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// the standard pipeline's passes
// ---------------------------------------------------------------------

/// Structural lint/verification over the *original* graph (analysis).
/// Error-severity findings abort compilation as
/// [`CompileError::InvalidGraph`]; warnings ride along on the artifact.
pub struct VerifyPass;

impl Pass for VerifyPass {
    fn name(&self) -> &'static str {
        "verify"
    }

    fn run(&self, cx: &mut PassCtx<'_>, _tel: Telemetry<'_>) -> Result<String, CompileError> {
        let diags = verify::graph_diagnostics(cx.graph());
        let errors: Vec<Diagnostic> =
            diags.iter().filter(|d| d.severity == Severity::Error).cloned().collect();
        if !errors.is_empty() {
            return Err(CompileError::InvalidGraph { diagnostics: errors });
        }
        let warnings = diags.len();
        cx.diags.extend(diags);
        Ok(if warnings == 0 {
            "clean".to_string()
        } else {
            format!("{warnings} warnings")
        })
    }
}

/// Dead-node elimination (transform; `cfg.opt` pipelines only).
pub struct DcePass;

impl Pass for DcePass {
    fn name(&self) -> &'static str {
        "dce"
    }

    fn run(&self, cx: &mut PassCtx<'_>, _tel: Telemetry<'_>) -> Result<String, CompileError> {
        match dce::run(cx.graph()) {
            Some((g, step)) => {
                let removed = step.orig_len - g.len();
                cx.commit_graph(g, step);
                Ok(format!("removed {removed} dead inputs"))
            }
            None => Ok("no dead nodes".to_string()),
        }
    }
}

/// Constant (input) replication for high-fanout sources (transform;
/// `cfg.opt` pipelines only).
pub struct ReplicateConstsPass;

impl Pass for ReplicateConstsPass {
    fn name(&self) -> &'static str {
        "replicate_consts"
    }

    fn run(&self, cx: &mut PassCtx<'_>, _tel: Telemetry<'_>) -> Result<String, CompileError> {
        match replicate_consts::run(cx.graph()) {
            Some((g, step, split)) => {
                let added = g.len() - step.orig_len;
                cx.commit_graph(g, step);
                Ok(format!("split {split} inputs into {added} extra replicas"))
            }
            None => Ok("no fanout above threshold".to_string()),
        }
    }
}

/// The paper's one-time criticality labeling, re-homed as an analysis
/// pass over the (possibly transformed) graph. The standard pipeline's
/// *only* labeling — `place` reuses these labels.
pub struct CriticalityPass;

impl Pass for CriticalityPass {
    fn name(&self) -> &'static str {
        "criticality"
    }

    fn run(&self, cx: &mut PassCtx<'_>, _tel: Telemetry<'_>) -> Result<String, CompileError> {
        let crit = criticality::criticality(cx.graph());
        let max = crit.iter().copied().max().unwrap_or(0);
        cx.crit = Some(crit);
        Ok(format!("max height {max}"))
    }
}

/// Multi-fabric graph partitioning (analysis over the current graph,
/// using the `criticality` pass's labels — slots in right after
/// [`CriticalityPass`]). Writes the node→shard assignment and the
/// boundary-edge table into [`PassCtx::partition`]; the sharded compile
/// driver ([`crate::shard::ShardedProgram::compile`]) extracts per-shard
/// subgraphs from it and runs the standard per-fabric pipeline on each.
pub struct PartitionPass {
    pub num_shards: usize,
}

impl PartitionPass {
    pub fn new(num_shards: usize) -> Self {
        Self { num_shards }
    }
}

impl Pass for PartitionPass {
    fn name(&self) -> &'static str {
        "partition"
    }

    fn run(&self, cx: &mut PassCtx<'_>, _tel: Telemetry<'_>) -> Result<String, CompileError> {
        let crit = cx.crit.as_deref().expect("criticality pass must run before partition");
        let part = partition::partition(cx.graph(), crit, self.num_shards, cx.cfg.seed);
        let detail = format!(
            "{} shards, cut {} edges ({} boundary values, weight {})",
            part.num_shards,
            part.cut_edges.len(),
            part.boundary_values(),
            part.cut_weight
        );
        cx.partition = Some(part);
        Ok(detail)
    }
}

/// Node→PE placement (analysis over the current graph, using the
/// `criticality` pass's labels). Fails hard on a per-PE local-index
/// overflow — a placement the 13-bit packet header cannot address —
/// and attaches capacity/flag-pressure warnings to the artifact.
pub struct PlacePass;

impl Pass for PlacePass {
    fn name(&self) -> &'static str {
        "place"
    }

    fn run(&self, cx: &mut PassCtx<'_>, tel: Telemetry<'_>) -> Result<String, CompileError> {
        let cfg = cx.cfg;
        let crit = cx.crit.as_deref().expect("criticality pass must run before place");
        let place = Placement::build_for_torus(
            cx.graph(),
            cfg.cols,
            cfg.rows,
            cfg.placement,
            cfg.local_order,
            cfg.seed,
            Some(crit),
        );
        for (pe, locals) in place.nodes_of.iter().enumerate() {
            if locals.len() > MAX_LOCAL_NODES {
                return Err(CompileError::LocalIndexOverflow {
                    pe,
                    nodes: locals.len(),
                    max: MAX_LOCAL_NODES,
                });
            }
        }
        let lints = verify::capacity_diagnostics(cx.graph(), &place, &cfg);
        cx.diags.extend(lints.into_iter().filter(|d| d.severity == Severity::Warning));
        let detail = if cfg.placement == PlacementPolicy::TrafficAware {
            let cost = placement_cost(cx.graph(), crit, &place.pe_of, cfg.cols, cfg.rows);
            if let Some(reg) = tel {
                reg.gauge("place.traffic.cost", cost as f64);
            }
            format!("{:?}, weighted-hop cost {cost}", cfg.placement)
        } else {
            format!("{:?}, max {} nodes/PE", cfg.placement, place.max_local_nodes())
        };
        cx.place = Some(place);
        Ok(detail)
    }
}

/// Per-PE BRAM image summaries (analysis over placement).
pub struct BramImagesPass;

impl Pass for BramImagesPass {
    fn name(&self) -> &'static str {
        "bram_images"
    }

    fn run(&self, cx: &mut PassCtx<'_>, _tel: Telemetry<'_>) -> Result<String, CompileError> {
        let place = cx.place.as_ref().expect("place pass must run before bram_images");
        let g = cx.graph();
        let pe_images: Vec<PeImage> = place
            .nodes_of
            .iter()
            .map(|locals| {
                let nodes = locals.len();
                let edges: usize = locals.iter().map(|&n| g.node(n).fanout.len()).sum();
                PeImage {
                    nodes,
                    edges,
                    graph_words: BramConfig::words_used(nodes, edges),
                }
            })
            .collect();
        let max = pe_images.iter().map(|i| i.graph_words).max().unwrap_or(0);
        cx.pe_images = Some(pe_images);
        Ok(format!("max {max} graph words/PE"))
    }
}

/// Capacity enforcement + the runtime-table bake (DESIGN.md §10). When
/// a transform rewrote the graph, the tables are baked *remapped*: the
/// image executes compiled ids while `global_of`/`seeds`/`values()`
/// speak original ids.
pub struct BakeTablesPass;

impl Pass for BakeTablesPass {
    fn name(&self) -> &'static str {
        "bake_tables"
    }

    fn run(&self, cx: &mut PassCtx<'_>, _tel: Telemetry<'_>) -> Result<String, CompileError> {
        let cfg = cx.cfg;
        let place = cx.place.as_ref().expect("place pass must run before bake_tables");
        let g = cx.graph();
        // the same check (one implementation) guards direct Simulator
        // construction, so compile-time and runtime verdicts agree
        if let Err(crate::sim::SimError::CapacityExceeded { pe, words_needed, words_available }) =
            crate::sim::check_capacity(g, place, &cfg)
        {
            return Err(CompileError::CapacityExceeded { pe, words_needed, words_available });
        }
        let tables = match cx.node_map() {
            Some(map) => RuntimeTables::build_remapped_shared(g, place, cfg.cols, cfg.rows, map),
            None => RuntimeTables::build_shared(g, place, cfg.cols, cfg.rows),
        };
        let detail = format!("{} routes, {} seeds", tables.routes.len(), tables.seeds.len());
        cx.tables = Some(tables);
        Ok(detail)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;

    #[test]
    fn standard_pipeline_order_tracks_opt() {
        let cfg = OverlayConfig::default();
        assert_eq!(
            PassManager::standard(&cfg).names(),
            ["verify", "criticality", "place", "bram_images", "bake_tables"]
        );
        let mut opt = cfg;
        opt.opt = true;
        assert_eq!(
            PassManager::standard(&opt).names(),
            [
                "verify",
                "dce",
                "replicate_consts",
                "criticality",
                "place",
                "bram_images",
                "bake_tables"
            ]
        );
    }

    #[test]
    fn node_map_composition() {
        // 4 originals; first map kills node 1, second splits (new) node 0
        // into two replicas
        let a = NodeMap {
            orig_len: 4,
            compiled_of: vec![0, NodeMap::DEAD, 1, 2],
            orig_of: vec![0, 2, 3],
        };
        let b = NodeMap {
            orig_len: 3,
            compiled_of: vec![0, 2, 3],
            orig_of: vec![0, 0, 1, 2],
        };
        let c = a.then(&b);
        assert_eq!(c.orig_len, 4);
        assert_eq!(c.compiled_of, vec![0, NodeMap::DEAD, 2, 3]);
        assert_eq!(c.orig_of, vec![0, 0, 2, 3]);
        assert!(c.is_live(0) && !c.is_live(1));
    }

    #[test]
    fn pipeline_runs_and_records_stats() {
        let mut g = DataflowGraph::new();
        let x = g.add_input(2.0);
        let y = g.add_input(3.0);
        g.op(Op::Mul, &[x, y]);
        let cfg = OverlayConfig::default().with_dims(2, 2);
        let mut cx = PassCtx::new(&g, cfg);
        PassManager::standard(&cfg).run(&mut cx, None).unwrap();
        assert_eq!(
            cx.stats.iter().map(|s| s.name).collect::<Vec<_>>(),
            ["verify", "criticality", "place", "bram_images", "bake_tables"]
        );
        assert!(cx.place.is_some() && cx.tables.is_some());
        assert!(cx.node_map().is_none(), "no transform ran");
        assert_eq!(cx.graph().len(), 3);
    }

    #[test]
    fn diagnostic_display_format() {
        let d = Diagnostic::error("cycle", Some(3), "operand 5 does not precede node".into());
        assert_eq!(d.to_string(), "error[cycle] node 3: operand 5 does not precede node");
        let w = Diagnostic::warning("capacity", None, "PE 0 over budget".into());
        assert_eq!(w.to_string(), "warning[capacity]: PE 0 over budget");
    }
}
