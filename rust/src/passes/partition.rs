//! Graph partitioning for sharded multi-fabric execution (DESIGN.md
//! §14): cut the dataflow DAG into `num_shards` balanced subgraphs
//! minimizing the criticality-weighted cut, so the values that must
//! cross the (slow) inter-fabric boundary channels are the ones the
//! critical path depends on least.
//!
//! Two phases, mirroring the traffic-aware placer
//! ([`crate::place::traffic`]):
//!
//! 1. **greedy grow** — walk nodes in topological order (builder order)
//!    and grow each shard BFS-style: a node joins the shard of one of
//!    its operands when that shard is under the balance cap, else the
//!    least-loaded shard, minimizing the weighted edges it would cut;
//! 2. **bounded annealing** — `min(200_000, 16·n)` relocation/swap
//!    moves under geometric cooling, seeded from the overlay seed, so
//!    the refinement is deterministic and cost-bounded.
//!
//! Any assignment is *legal*: every cross-shard edge becomes a proxy
//! input in the consumer shard (see [`crate::shard`]), and because
//! builder order is topological, interleaving proxies at their
//! producer's original id keeps every shard subgraph topological by
//! construction.

use crate::graph::{DataflowGraph, NodeId, NodeKind};
use crate::util::rng::Rng;

/// One dataflow edge that crosses shards: `src` (producer) and `dst`
/// (consumer) are *original-graph* node ids; `slot` is the consumer's
/// operand slot. Listed in (src id, fanout order) — deterministic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CutEdge {
    pub src: NodeId,
    pub dst: NodeId,
    pub slot: u8,
}

/// The result of [`partition`]: a total node→shard assignment plus the
/// boundary-edge table and its cost.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    pub num_shards: usize,
    /// node id → shard index (total over the graph)
    pub shard_of: Vec<u32>,
    /// every edge crossing shards, in (src, fanout order)
    pub cut_edges: Vec<CutEdge>,
    /// criticality-weighted cost of the cut (`Σ 1 + crit[src]`)
    pub cut_weight: u64,
    /// total edge count of the graph (for cut-fraction reporting)
    pub total_edges: usize,
}

impl Partition {
    /// Nodes per shard.
    pub fn shard_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.num_shards];
        for &s in &self.shard_of {
            sizes[s as usize] += 1;
        }
        sizes
    }

    /// Distinct `(producer, consumer shard)` pairs — each is one value
    /// that must physically cross a boundary channel (a producer fanning
    /// out to many consumers in one shard crosses once).
    pub fn boundary_values(&self) -> usize {
        let mut pairs: Vec<(NodeId, u32)> = self
            .cut_edges
            .iter()
            .map(|e| (e.src, self.shard_of[e.dst as usize]))
            .collect();
        pairs.sort_unstable();
        pairs.dedup();
        pairs.len()
    }
}

/// Weight of a cut edge out of `src`: cutting a critical producer costs
/// more (its consumers wait a full boundary round-trip on the critical
/// path). Same shape as the traffic placer's edge weight.
#[inline]
fn weight(crit: &[u32], src: NodeId) -> u64 {
    1 + crit[src as usize] as u64
}

/// Exact criticality-weighted cut cost of an assignment.
pub fn partition_cost(g: &DataflowGraph, crit: &[u32], shard_of: &[u32]) -> u64 {
    let mut cost = 0u64;
    for (src, node) in g.nodes().iter().enumerate() {
        for &(dst, _) in &node.fanout {
            if shard_of[src] != shard_of[dst as usize] {
                cost += weight(crit, src as NodeId);
            }
        }
    }
    cost
}

/// Cut `g` into `num_shards` balanced subgraphs minimizing the
/// criticality-weighted cut. Deterministic for fixed inputs (the
/// annealing RNG is seeded from `seed`); `num_shards` is clamped to the
/// node count and every shard is guaranteed non-empty.
pub fn partition(g: &DataflowGraph, crit: &[u32], num_shards: usize, seed: u64) -> Partition {
    let n = g.len();
    assert_eq!(crit.len(), n, "criticality labels cover the graph");
    let k = num_shards.max(1).min(n.max(1));
    let total_edges = g.num_edges();
    if k <= 1 {
        return Partition {
            num_shards: 1,
            shard_of: vec![0; n],
            cut_edges: Vec::new(),
            cut_weight: 0,
            total_edges,
        };
    }

    // node balance cap: no shard may exceed ceil(n / k) nodes, so every
    // fabric sees a comparable per-PE load after its own placement
    let cap = n.div_ceil(k);
    let mut shard = vec![0u32; n];
    let mut load = vec![0usize; k];

    // ---- phase 1: greedy BFS-grow in topological (builder) order ----
    // candidates: each operand's shard while under cap (joining it cuts
    // nothing on that edge), plus the least-loaded shard as the spread
    // fallback; choose min (added cut weight, load, index).
    for v in 0..n {
        let mut cands: Vec<u32> = Vec::with_capacity(3);
        if let NodeKind::Operation { op, src } = g.node(v as NodeId).kind {
            for &u in &src[..op.arity()] {
                let s = shard[u as usize];
                if load[s as usize] < cap && !cands.contains(&s) {
                    cands.push(s);
                }
            }
        }
        let spread = (0..k as u32)
            .min_by_key(|&s| (load[s as usize], s))
            .unwrap();
        if load[spread as usize] < cap && !cands.contains(&spread) {
            cands.push(spread);
        }
        if cands.is_empty() {
            // every candidate at cap (possible only transiently near the
            // end): fall back to the least-loaded shard regardless
            cands.push(spread);
        }
        let best = cands
            .iter()
            .copied()
            .min_by_key(|&s| {
                let mut cut = 0u64;
                if let NodeKind::Operation { op, src } = g.node(v as NodeId).kind {
                    for &u in &src[..op.arity()] {
                        if shard[u as usize] != s {
                            cut += weight(crit, u);
                        }
                    }
                }
                (cut, load[s as usize], s)
            })
            .unwrap();
        shard[v] = best;
        load[best as usize] += 1;
    }

    // ---- phase 2: bounded deterministic annealing ----
    // undirected incident lists with weights (both directions of every
    // edge), the same refinement structure as the traffic placer.
    struct Inc {
        other: u32,
        w: u64,
    }
    let mut inc: Vec<Vec<Inc>> = (0..n).map(|_| Vec::new()).collect();
    for (src, node) in g.nodes().iter().enumerate() {
        let w = weight(crit, src as NodeId);
        for &(dst, _) in &node.fanout {
            inc[src].push(Inc { other: dst, w });
            inc[dst as usize].push(Inc { other: src as u32, w });
        }
    }
    // cut contribution of node v under shard s
    let node_cost = |shard: &[u32], v: usize, s: u32| -> u64 {
        inc[v]
            .iter()
            .filter(|e| shard[e.other as usize] != s)
            .map(|e| e.w)
            .sum()
    };
    // total weight of edges directly between a and b (swap correction)
    let between = |a: usize, b: usize| -> u64 {
        inc[a]
            .iter()
            .filter(|e| e.other as usize == b)
            .map(|e| e.w)
            .sum()
    };

    let mut cost = partition_cost(g, crit, &shard) as i64;
    let moves = 200_000usize.min(16 * n.max(1));
    let mut rng = Rng::seed_from_u64(seed ^ 0x5348_4152_4453); // "SHARDS"
    let mut temp = (cost as f64 / total_edges.max(1) as f64).max(1.0);
    let alpha = 0.01f64.powf(1.0 / moves.max(1) as f64);
    for _ in 0..moves {
        temp *= alpha;
        if rng.gen_bool(0.5) {
            // relocation: move v to shard t (capacity- and
            // non-emptiness-preserving)
            let v = rng.gen_range(n);
            let s = shard[v];
            let t = rng.gen_range(k) as u32;
            if t == s || load[t as usize] >= cap || load[s as usize] <= 1 {
                continue;
            }
            let delta = node_cost(&shard, v, t) as i64 - node_cost(&shard, v, s) as i64;
            if delta <= 0 || rng.gen_f64() < (-(delta as f64) / temp).exp() {
                shard[v] = t;
                load[s as usize] -= 1;
                load[t as usize] += 1;
                cost += delta;
            }
        } else {
            // swap: exchange the shards of a and b (balance-preserving)
            let a = rng.gen_range(n);
            let b = rng.gen_range(n);
            let (s, t) = (shard[a], shard[b]);
            if s == t {
                continue;
            }
            let delta = node_cost(&shard, a, t) as i64 + node_cost(&shard, b, s) as i64
                - node_cost(&shard, a, s) as i64
                - node_cost(&shard, b, t) as i64
                + 2 * between(a, b) as i64;
            if delta <= 0 || rng.gen_f64() < (-(delta as f64) / temp).exp() {
                shard[a] = t;
                shard[b] = s;
                cost += delta;
            }
        }
    }
    debug_assert_eq!(cost, partition_cost(g, crit, &shard) as i64);

    // every shard non-empty: steal the highest-id node from the largest
    // shard (deterministic; can only trigger for tiny graphs)
    loop {
        let mut sizes = vec![0usize; k];
        for &s in &shard {
            sizes[s as usize] += 1;
        }
        let Some(empty) = sizes.iter().position(|&c| c == 0) else {
            break;
        };
        let donor = (0..k).max_by_key(|&s| (sizes[s], s)).unwrap() as u32;
        let v = (0..n).rev().find(|&v| shard[v] == donor).unwrap();
        shard[v] = empty as u32;
    }

    // exact boundary-edge table in (src, fanout order)
    let mut cut_edges = Vec::new();
    let mut cut_weight = 0u64;
    for (src, node) in g.nodes().iter().enumerate() {
        for &(dst, slot) in &node.fanout {
            if shard[src] != shard[dst as usize] {
                cut_edges.push(CutEdge { src: src as NodeId, dst, slot });
                cut_weight += weight(crit, src as NodeId);
            }
        }
    }
    Partition {
        num_shards: k,
        shard_of: shard,
        cut_edges,
        cut_weight,
        total_edges,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::criticality::criticality;
    use crate::workload::{layered_random, lu_factorization_graph, SparseMatrix};

    fn check_partition(g: &DataflowGraph, p: &Partition, k: usize) {
        assert_eq!(p.shard_of.len(), g.len());
        let sizes = p.shard_sizes();
        assert_eq!(sizes.len(), k.min(g.len()));
        assert!(sizes.iter().all(|&s| s > 0), "no empty shard: {sizes:?}");
        assert!(
            *sizes.iter().max().unwrap() <= g.len().div_ceil(k) + 1,
            "balance cap (±1 for the non-empty fixup): {sizes:?}"
        );
        // the cut table is exactly the crossing edges
        for e in &p.cut_edges {
            assert_ne!(p.shard_of[e.src as usize], p.shard_of[e.dst as usize]);
        }
        let crossing = g
            .nodes()
            .iter()
            .enumerate()
            .flat_map(|(src, n)| n.fanout.iter().map(move |&(dst, _)| (src, dst)))
            .filter(|&(s, d)| p.shard_of[s] != p.shard_of[d as usize])
            .count();
        assert_eq!(p.cut_edges.len(), crossing);
    }

    #[test]
    fn single_shard_is_trivial() {
        let g = layered_random(8, 4, 12, 2, 1);
        let crit = criticality(&g);
        let p = partition(&g, &crit, 1, 0);
        assert_eq!(p.num_shards, 1);
        assert!(p.cut_edges.is_empty());
        assert_eq!(p.cut_weight, 0);
        assert!(p.shard_of.iter().all(|&s| s == 0));
    }

    #[test]
    fn partitions_are_balanced_and_consistent() {
        let g = layered_random(16, 8, 32, 2, 3);
        let crit = criticality(&g);
        for k in [2, 3, 4, 7] {
            let p = partition(&g, &crit, k, 5);
            check_partition(&g, &p, k);
            assert_eq!(p.cut_weight, partition_cost(&g, &crit, &p.shard_of));
            assert!(p.boundary_values() <= p.cut_edges.len());
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let m = SparseMatrix::banded(48, 3, 0.9, 7);
        let (g, _) = lu_factorization_graph(&m);
        let crit = criticality(&g);
        let a = partition(&g, &crit, 4, 9);
        let b = partition(&g, &crit, 4, 9);
        assert_eq!(a, b, "same seed, same partition");
    }

    #[test]
    fn annealing_beats_or_matches_round_robin() {
        let g = layered_random(24, 10, 48, 3, 11);
        let crit = criticality(&g);
        let p = partition(&g, &crit, 4, 0);
        let rr: Vec<u32> = (0..g.len() as u32).map(|v| v % 4).collect();
        assert!(
            p.cut_weight <= partition_cost(&g, &crit, &rr),
            "grown+annealed cut must not lose to round-robin"
        );
    }

    #[test]
    fn more_shards_than_nodes_clamps() {
        let mut g = DataflowGraph::new();
        let a = g.add_input(1.0);
        let b = g.add_input(2.0);
        g.op(crate::graph::Op::Add, &[a, b]);
        let crit = criticality(&g);
        let p = partition(&g, &crit, 16, 0);
        assert_eq!(p.num_shards, 3, "clamped to the node count");
        assert!(p.shard_sizes().iter().all(|&s| s == 1));
    }
}
