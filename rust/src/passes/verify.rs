//! Static graph verification: the lint catalog behind the `verify`
//! pass and `tdp check`.
//!
//! [`graph_diagnostics`] diagnoses a graph *structurally* — no overlay
//! needed — and is total over malformed graphs (the `tdp check` loader,
//! [`crate::graph::graph_from_json_raw`], deliberately loads cycles and
//! dangling ids so they can be reported here instead of dying at parse
//! time). [`capacity_diagnostics`] adds the overlay-dependent lints:
//! per-PE graph-memory pressure and OoO flag-word coverage.
//!
//! Every finding carries a stable `code` slug; `tdp check --format
//! json` consumers and the CI known-bad fixtures key off these:
//!
//! | code               | severity | meaning |
//! |--------------------|----------|---------|
//! | `empty`            | error    | graph has no nodes |
//! | `dangling-operand` | error    | operand id ≥ node count |
//! | `cycle`            | error    | operand id ≥ own id (forward/self reference — in this topologically-indexed IR, exactly a combinational cycle) |
//! | `dangling-edge`    | error    | fanout edge to an id ≥ node count |
//! | `edge-to-input`    | error    | fanout edge delivers into an Input node |
//! | `slot-range`       | error    | fanout edge targets a slot ≥ destination arity |
//! | `edge-mismatch`    | error    | fanout edge (u→v, slot) but v's operand in that slot is not u |
//! | `missing-operand`  | error    | an operand slot no fanout edge ever fills — the node can never fire |
//! | `dup-delivery`     | error    | one operand slot filled by multiple fanout edges |
//! | `unreachable`      | error    | operands are locally well-formed but transitively depend on a broken node |
//! | `dead-input`       | warning  | input with no consumers (DCE candidate) |
//! | `high-fanout`      | warning  | fanout > 256 (serialization hotspot; replication candidate) |
//! | `capacity`         | error/warning | PE graph memory over budget (error iff `enforce_capacity`) |
//! | `local-overflow`   | error    | PE holds more nodes than a 13-bit local index addresses |
//! | `flag-overflow`    | warning  | OoO flag vectors cannot cover every local node |
//! | `shard-hint`       | warning  | capacity overflow summary: estimated shard count that would fit ([`crate::program::Program::min_shards`]) |
//!
//! Reporting is capped per code (first [`MAX_PER_CODE`] findings, then
//! one summary diagnostic with the suppressed count) so a pathological
//! graph produces a readable report, not a million-line one.

use super::{Diagnostic, Severity};
use crate::config::OverlayConfig;
use crate::graph::{DataflowGraph, NodeKind};
use crate::noc::MAX_LOCAL_NODES;
use crate::pe::BramConfig;
use crate::place::Placement;
use crate::sched::SchedulerKind;

/// Per-code reporting cap; further findings fold into a summary line.
pub const MAX_PER_CODE: usize = 8;

/// Fanout above this is flagged as a serialization hotspot (warning).
pub const HIGH_FANOUT: usize = 256;

/// Collects diagnostics with a per-code cap; suppressed counts fold
/// into one trailing summary diagnostic per code.
struct Emitter {
    out: Vec<Diagnostic>,
    // (code, severity, total) in first-seen order; linear scan is fine
    // for a catalog of ~15 codes
    counts: Vec<(&'static str, Severity, usize)>,
}

impl Emitter {
    fn new() -> Self {
        Self { out: Vec::new(), counts: Vec::new() }
    }

    fn emit(&mut self, d: Diagnostic) {
        match self.counts.iter_mut().find(|(c, ..)| *c == d.code) {
            Some((_, _, total)) => {
                *total += 1;
                if *total <= MAX_PER_CODE {
                    self.out.push(d);
                }
            }
            None => {
                self.counts.push((d.code, d.severity, 1));
                self.out.push(d);
            }
        }
    }

    fn finish(mut self) -> Vec<Diagnostic> {
        for &(code, severity, total) in &self.counts {
            if total > MAX_PER_CODE {
                self.out.push(Diagnostic {
                    severity,
                    code,
                    node: None,
                    message: format!(
                        "... and {} more `{code}` diagnostics (showing first {MAX_PER_CODE})",
                        total - MAX_PER_CODE
                    ),
                });
            }
        }
        self.out
    }
}

/// Structurally diagnose `g`. Returns every finding (errors and
/// warnings), capped per code; an empty vec means the graph is clean.
pub fn graph_diagnostics(g: &DataflowGraph) -> Vec<Diagnostic> {
    let n = g.len();
    if n == 0 {
        return vec![Diagnostic::error("empty", None, "graph has no nodes".to_string())];
    }
    let mut em = Emitter::new();
    let nid = |i: usize| Some(i as u32);

    // operand-side structural checks + per-slot delivery counts
    // (delivered[i] holds counts for node i's operand slots)
    let mut delivered: Vec<[u8; 2]> = vec![[0, 0]; n];
    for i in 0..n {
        for &(dst, slot) in &g.node(i as u32).fanout {
            let (d, s) = (dst as usize, slot as usize);
            if d >= n {
                em.emit(Diagnostic::error(
                    "dangling-edge",
                    nid(i),
                    format!("fanout edge to node {dst} but graph has {n} nodes"),
                ));
                continue;
            }
            match g.node(dst).kind {
                NodeKind::Input { .. } => em.emit(Diagnostic::error(
                    "edge-to-input",
                    nid(i),
                    format!("fanout edge delivers into input node {dst}"),
                )),
                NodeKind::Operation { op, src } => {
                    if s >= op.arity() {
                        em.emit(Diagnostic::error(
                            "slot-range",
                            nid(i),
                            format!(
                                "fanout edge targets slot {s} of node {dst} but {} has arity {}",
                                op.name(),
                                op.arity()
                            ),
                        ));
                    } else if src[s] != i as u32 {
                        em.emit(Diagnostic::error(
                            "edge-mismatch",
                            nid(i),
                            format!(
                                "fanout edge claims slot {s} of node {dst}, whose operand there is node {}",
                                src[s]
                            ),
                        ));
                    } else {
                        delivered[d][s] = delivered[d][s].saturating_add(1);
                    }
                }
            }
        }
    }

    // computable[i]: node i can produce a value (transitive liveness DP)
    let mut computable = vec![false; n];
    for i in 0..n {
        match g.node(i as u32).kind {
            NodeKind::Input { .. } => {
                computable[i] = true;
                if g.node(i as u32).fanout.is_empty() {
                    em.emit(Diagnostic::warning(
                        "dead-input",
                        nid(i),
                        "input has no consumers (dead-code-elimination candidate)".to_string(),
                    ));
                }
            }
            NodeKind::Operation { op, src } => {
                let mut locally_ok = true;
                let mut feeds_ok = true;
                for (slot, &s) in src[..op.arity()].iter().enumerate() {
                    if (s as usize) >= n {
                        em.emit(Diagnostic::error(
                            "dangling-operand",
                            nid(i),
                            format!("operand {slot} is node {s} but graph has {n} nodes"),
                        ));
                        locally_ok = false;
                        continue;
                    }
                    if (s as usize) >= i {
                        em.emit(Diagnostic::error(
                            "cycle",
                            nid(i),
                            format!(
                                "operand {slot} is node {s}, which does not precede this node \
                                 (combinational cycle in the topological index order)"
                            ),
                        ));
                        locally_ok = false;
                        continue;
                    }
                    feeds_ok &= computable[s as usize];
                    match delivered[i][slot] {
                        0 => {
                            em.emit(Diagnostic::error(
                                "missing-operand",
                                nid(i),
                                format!(
                                    "no fanout edge of node {s} delivers operand {slot}; \
                                     the node can never fire"
                                ),
                            ));
                            locally_ok = false;
                        }
                        1 => {}
                        k => {
                            em.emit(Diagnostic::error(
                                "dup-delivery",
                                nid(i),
                                format!("operand {slot} is delivered by {k} fanout edges"),
                            ));
                            locally_ok = false;
                        }
                    }
                }
                if locally_ok && !feeds_ok {
                    em.emit(Diagnostic::error(
                        "unreachable",
                        nid(i),
                        "operands are well-formed but transitively depend on a broken node; \
                         this output can never be produced"
                            .to_string(),
                    ));
                }
                computable[i] = locally_ok && feeds_ok;
            }
        }
        if g.node(i as u32).fanout.len() > HIGH_FANOUT {
            em.emit(Diagnostic::warning(
                "high-fanout",
                nid(i),
                format!(
                    "fanout {} exceeds {HIGH_FANOUT}; result delivery serializes on the \
                     Hoplite exit port (constant-replication candidate)",
                    g.node(i as u32).fanout.len()
                ),
            ));
        }
    }
    em.finish()
}

/// Overlay-dependent lints over a concrete placement: per-PE
/// graph-memory pressure (`capacity`: error iff `cfg.enforce_capacity`,
/// else warning), 13-bit local-index overflow (`local-overflow`, always
/// an error) and — OoO only — flag-vector coverage (`flag-overflow`,
/// warning). The capacity message names the PE and quantifies the
/// overflow in words *and* approximate nodes, which is also how
/// `Program::fit_violations` reports a failed fit.
pub fn capacity_diagnostics(
    g: &DataflowGraph,
    place: &Placement,
    cfg: &OverlayConfig,
) -> Vec<Diagnostic> {
    let mut em = Emitter::new();
    let budget = cfg.bram.graph_words(cfg.scheduler);
    let mut total_words = 0usize;
    let mut overflowed = false;
    // OoO flag vectors: 2 per node (RDY + fanout-pending), so coverage
    // is half the flag bits
    let flag_nodes = (cfg.bram.flag_words() / 2) * cfg.bram.flag_bits_used;
    for (pe, locals) in place.nodes_of.iter().enumerate() {
        let nodes = locals.len();
        let edges: usize = locals.iter().map(|&id| g.node(id).fanout.len()).sum();
        let words = BramConfig::words_used(nodes, edges);
        total_words += words;
        if words > budget {
            overflowed = true;
            let over = words - budget;
            let words_per_node = (words / nodes.max(1)).max(1);
            let severity =
                if cfg.enforce_capacity { Severity::Error } else { Severity::Warning };
            em.emit(Diagnostic {
                severity,
                code: "capacity",
                node: None,
                message: format!(
                    "PE {pe} needs {words} graph words but has {budget}: over by {over} words \
                     (≈{} nodes at this PE's {} words/node average)",
                    over.div_ceil(words_per_node),
                    words_per_node
                ),
            });
        }
        if nodes > MAX_LOCAL_NODES {
            em.emit(Diagnostic::error(
                "local-overflow",
                None,
                format!(
                    "PE {pe} holds {nodes} nodes but the 13-bit packet local index \
                     addresses only {MAX_LOCAL_NODES}"
                ),
            ));
        }
        if cfg.scheduler == SchedulerKind::OutOfOrder && nodes > flag_nodes {
            em.emit(Diagnostic::warning(
                "flag-overflow",
                None,
                format!(
                    "PE {pe} holds {nodes} nodes but the OoO flag vectors cover only \
                     {flag_nodes}; RDY/pending state would spill out of the flag words"
                ),
            ));
        }
    }
    // The actionable summary behind any capacity overflow: how many
    // fabrics of this geometry sharded execution would need (same
    // estimate as `Program::min_shards` — boundary proxies can nudge the
    // real partition slightly higher).
    if overflowed {
        let per_fabric = budget * place.nodes_of.len();
        let shards =
            if per_fabric == 0 { usize::MAX } else { total_words.div_ceil(per_fabric).max(2) };
        em.emit(Diagnostic::warning(
            "shard-hint",
            None,
            format!(
                "graph needs {total_words} graph words but one {}x{} fabric holds {per_fabric}: \
                 sharded execution needs an estimated {shards} fabrics \
                 (set `shards = {shards}`, or leave capacity unenforced to auto-shard)",
                cfg.cols, cfg.rows
            ),
        ));
    }
    em.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{graph_from_json_raw, Op};
    use crate::place::{LocalOrder, PlacementPolicy};

    fn codes(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.code).collect()
    }

    #[test]
    fn builder_graphs_are_clean() {
        // hand-built diamond: fully clean (no errors, no warnings)
        let mut g = DataflowGraph::new();
        let x = g.add_input(2.0);
        let a = g.op(Op::Neg, &[x]);
        let b = g.op(Op::Add, &[x, a]);
        g.op(Op::Mul, &[a, b]);
        assert!(graph_diagnostics(&g).is_empty(), "{:?}", graph_diagnostics(&g));
        // builder-constructed workloads can carry advisory warnings
        // (dead inputs) but never errors
        let g = crate::workload::layered_random(16, 4, 32, 2, 7);
        assert!(
            graph_diagnostics(&g).iter().all(|d| d.severity == Severity::Warning),
            "{:?}",
            graph_diagnostics(&g)
        );
    }

    #[test]
    fn empty_graph_is_an_error() {
        let g = DataflowGraph::new();
        assert_eq!(codes(&graph_diagnostics(&g)), ["empty"]);
    }

    #[test]
    fn cycle_and_downstream_unreachability() {
        // node 1 references node 2 (forward → cycle); node 2 is locally
        // fine but feeds off the broken node 1 → unreachable
        let bad = r#"{"nodes":[{"in":1.0},{"op":"ADD","src":[2,0]},{"op":"MUL","src":[1,0]}]}"#;
        let g = graph_from_json_raw(bad).unwrap();
        let diags = graph_diagnostics(&g);
        assert!(codes(&diags).contains(&"cycle"), "{diags:?}");
        assert!(codes(&diags).contains(&"unreachable"), "{diags:?}");
        assert!(diags.iter().any(|d| d.code == "cycle" && d.node == Some(1)));
    }

    #[test]
    fn dangling_operand_detected() {
        let g = graph_from_json_raw(r#"{"nodes":[{"in":1.0},{"op":"NEG","src":[9]}]}"#).unwrap();
        let diags = graph_diagnostics(&g);
        assert!(codes(&diags).contains(&"dangling-operand"), "{diags:?}");
        // the input feeds nobody → also a dead-input warning
        assert!(codes(&diags).contains(&"dead-input"), "{diags:?}");
    }

    #[test]
    fn hand_corrupted_fanout_is_caught() {
        use crate::graph::{Node, NodeKind};
        // node 1 = NEG(0), but node 0's fanout lies about the slot and
        // never actually delivers operand 0
        let nodes = vec![
            Node { kind: NodeKind::Input { value: 1.0 }, fanout: vec![(1, 1)] },
            Node { kind: NodeKind::Operation { op: Op::Neg, src: [0, 0] }, fanout: vec![] },
        ];
        let g = DataflowGraph::from_raw_nodes(nodes);
        let diags = graph_diagnostics(&g);
        assert!(codes(&diags).contains(&"slot-range"), "{diags:?}");
        assert!(codes(&diags).contains(&"missing-operand"), "{diags:?}");
    }

    #[test]
    fn per_code_cap_folds_into_summary() {
        // 20 ops all referencing a dangling id → capped at MAX_PER_CODE
        // plus one summary diagnostic
        let mut nodes = vec![r#"{"in":1.0}"#.to_string()];
        for _ in 0..20 {
            nodes.push(r#"{"op":"NEG","src":[99]}"#.to_string());
        }
        let json = format!(r#"{{"nodes":[{}]}}"#, nodes.join(","));
        let g = graph_from_json_raw(&json).unwrap();
        let dangling: Vec<_> =
            graph_diagnostics(&g).into_iter().filter(|d| d.code == "dangling-operand").collect();
        assert_eq!(dangling.len(), MAX_PER_CODE + 1);
        assert!(dangling.last().unwrap().message.contains("12 more"));
    }

    #[test]
    fn capacity_lint_names_pe_and_overflow() {
        // 1×1 overlay: everything lands on PE 0 and overflows the budget
        let g = crate::workload::layered_random(800, 400, 1600, 2, 0);
        let mut cfg = OverlayConfig::default().with_dims(1, 1);
        cfg.enforce_capacity = true;
        let place = Placement::build(
            &g,
            1,
            PlacementPolicy::RoundRobin,
            LocalOrder::ByIndex,
            0,
        );
        let diags = capacity_diagnostics(&g, &place, &cfg);
        let cap = diags.iter().find(|d| d.code == "capacity").expect("capacity diagnostic");
        assert_eq!(cap.severity, Severity::Error);
        assert!(cap.message.contains("PE 0"), "{}", cap.message);
        assert!(cap.message.contains("over by"), "{}", cap.message);
        // any overflow also yields the actionable shard-count summary
        let hint = diags.iter().find(|d| d.code == "shard-hint").expect("shard hint");
        assert_eq!(hint.severity, Severity::Warning);
        assert!(hint.message.contains("shards ="), "{}", hint.message);
        // without enforcement the same finding is advisory
        cfg.enforce_capacity = false;
        let diags = capacity_diagnostics(&g, &place, &cfg);
        assert_eq!(
            diags.iter().find(|d| d.code == "capacity").unwrap().severity,
            Severity::Warning
        );
        // a fitting graph emits no hint
        let small = crate::workload::layered_random(4, 2, 8, 2, 0);
        let cfg16 = OverlayConfig::default();
        let place16 = Placement::build(
            &small,
            cfg16.num_pes(),
            PlacementPolicy::RoundRobin,
            LocalOrder::ByIndex,
            0,
        );
        assert!(capacity_diagnostics(&small, &place16, &cfg16)
            .iter()
            .all(|d| d.code != "shard-hint"));
    }
}
