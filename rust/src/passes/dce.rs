//! Dead-node elimination (transform pass).
//!
//! In this IR dead code has exactly one shape: an *input with no
//! consumers*. Operation nodes with empty fanout are the graph's
//! outputs (their results are what the run produces), and an operation
//! can never be unreferenced-yet-present in a builder-constructed
//! graph without being an output. Dead inputs, however, occur
//! naturally — sparse-matrix rows whose entries all got folded,
//! generator over-allocation — and each one wastes two BRAM graph
//! words plus a seed packet at init time on every PE it lands on.
//!
//! Runs only on verify-clean graphs (the standard pipeline orders it
//! after the `verify` pass), so operand ids are known in-range.
//! Returns `None` when nothing is dead — the pipeline then keeps the
//! borrowed original graph and records no id remap.

use super::NodeMap;
use crate::graph::{DataflowGraph, NodeKind};

/// Remove dead inputs from `g`. Returns the rewritten graph and the
/// old→new [`NodeMap`] step, or `None` if nothing was removed.
pub fn run(g: &DataflowGraph) -> Option<(DataflowGraph, NodeMap)> {
    let n = g.len();
    let dead: Vec<bool> = (0..n as u32)
        .map(|i| {
            matches!(g.node(i).kind, NodeKind::Input { .. }) && g.node(i).fanout.is_empty()
        })
        .collect();
    if !dead.contains(&true) {
        return None;
    }
    let mut compiled_of = vec![NodeMap::DEAD; n];
    let mut orig_of = Vec::new();
    let mut nodes = Vec::with_capacity(n);
    for i in 0..n {
        if dead[i] {
            continue;
        }
        compiled_of[i] = nodes.len() as u32;
        orig_of.push(i as u32);
        nodes.push(g.node(i as u32).clone());
    }
    // remap operand and fanout ids; dead nodes are unreferenced by
    // definition, so no remap target is ever DEAD
    for node in &mut nodes {
        if let NodeKind::Operation { src, .. } = &mut node.kind {
            src[0] = compiled_of[src[0] as usize];
            src[1] = compiled_of[src[1] as usize];
        }
        for (dst, _) in &mut node.fanout {
            *dst = compiled_of[*dst as usize];
        }
    }
    Some((
        DataflowGraph::from_raw_nodes(nodes),
        NodeMap { orig_len: n, compiled_of, orig_of },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;
    use crate::passes::verify::graph_diagnostics;

    #[test]
    fn removes_exactly_the_dead_inputs() {
        let mut g = DataflowGraph::new();
        let a = g.add_input(1.0);
        let _dead1 = g.add_input(9.0);
        let b = g.add_input(2.0);
        let s = g.op(Op::Add, &[a, b]);
        let _dead2 = g.add_input(-3.0);
        g.op(Op::Neg, &[s]);
        let before = g.evaluate();

        let (g2, map) = run(&g).expect("two dead inputs");
        assert_eq!(g2.len(), 4);
        assert!(graph_diagnostics(&g2).is_empty(), "{:?}", graph_diagnostics(&g2));
        assert_eq!(map.compiled_of, vec![0, NodeMap::DEAD, 1, 2, NodeMap::DEAD, 3]);
        assert_eq!(map.orig_of, vec![0, 2, 3, 5]);
        // live nodes compute the same values, addressed through the map
        let after = g2.evaluate();
        for orig in 0..g.len() {
            if map.is_live(orig as u32) {
                let c = map.compiled_of[orig] as usize;
                assert_eq!(after[c], before[orig], "node {orig}");
            }
        }
    }

    #[test]
    fn clean_graph_is_untouched() {
        let mut g = DataflowGraph::new();
        let a = g.add_input(1.0);
        g.op(Op::Neg, &[a]);
        assert!(run(&g).is_none());
    }

    #[test]
    fn fanout_order_survives_the_remap() {
        // route tables are derived from fanout order; the rewrite must
        // keep each surviving node's fanout list in its original order
        let mut g = DataflowGraph::new();
        let _dead = g.add_input(0.0);
        let x = g.add_input(5.0);
        let p = g.op(Op::Neg, &[x]);
        let q = g.op(Op::Add, &[x, p]);
        g.op(Op::Mul, &[x, q]);
        let (g2, map) = run(&g).unwrap();
        let fan: Vec<(u32, u8)> = g2.node(map.compiled_of[x as usize]).fanout.clone();
        assert_eq!(
            fan,
            vec![
                (map.compiled_of[p as usize], 0),
                (map.compiled_of[q as usize], 0),
                (map.compiled_of[4], 0)
            ]
        );
    }
}
