//! Constant (input) replication (transform pass).
//!
//! A result leaves its PE through a single Hoplite exit port, one
//! packet per cycle — a node with fanout *f* serializes for *f* cycles
//! (§II-C). For operation nodes that serialization is inherent, but an
//! *input* is pure state: it can be cloned freely. This pass splits
//! every input whose fanout exceeds [`FANOUT_THRESHOLD`] into
//! `ceil(f / threshold)` replicas, each serving a contiguous chunk of
//! the original fanout list, so the placer can spread the copies
//! across PEs and the per-source serialization chain shortens by ~k×.
//!
//! Replicas sit at the original input's position in the node order
//! (original id order is preserved, so topological indexing survives).
//! The [`NodeMap`] step maps the original to its *first* replica and
//! every replica back to the original — all replicas necessarily carry
//! the same value, so `values()` in original-id space stays
//! well-defined no matter which replica a reader resolves through.
//!
//! Like [`super::dce`], requires a verify-clean graph.

use super::NodeMap;
use crate::graph::{DataflowGraph, Node, NodeKind};
use std::collections::HashMap;

/// Inputs with fanout above this get replicated. Matches the point
/// where exit-port serialization (one packet/cycle) starts to dominate
/// a 256-PE overlay's typical critical path.
pub const FANOUT_THRESHOLD: usize = 64;

/// Split high-fanout inputs in `g`. Returns the rewritten graph, the
/// old→new [`NodeMap`] step, and how many inputs were split — or
/// `None` if no input crosses the threshold.
pub fn run(g: &DataflowGraph) -> Option<(DataflowGraph, NodeMap, usize)> {
    let n = g.len();
    let mut split_count = 0usize;
    let mut replicas = vec![1usize; n];
    for i in 0..n {
        let node = g.node(i as u32);
        if matches!(node.kind, NodeKind::Input { .. }) && node.fanout.len() > FANOUT_THRESHOLD {
            replicas[i] = node.fanout.len().div_ceil(FANOUT_THRESHOLD);
            split_count += 1;
        }
    }
    if split_count == 0 {
        return None;
    }

    let mut compiled_of = vec![0u32; n];
    let mut orig_of: Vec<u32> = Vec::new();
    for (i, &k) in replicas.iter().enumerate() {
        compiled_of[i] = orig_of.len() as u32;
        orig_of.resize(orig_of.len() + k, i as u32);
    }

    // each fanout edge of a split input is served by one replica:
    // contiguous chunks in original fanout-list order. HashMap is
    // lookup-only below, so iteration order never matters.
    let mut edge_src: HashMap<(u32, u8), u32> = HashMap::new();
    for (i, &k) in replicas.iter().enumerate() {
        if k == 1 {
            continue;
        }
        let fan = &g.node(i as u32).fanout;
        let chunk = fan.len().div_ceil(k);
        for (e, &(dst, slot)) in fan.iter().enumerate() {
            edge_src.insert((dst, slot), compiled_of[i] + (e / chunk) as u32);
        }
    }

    // operation nodes are never replicated, so this emits each exactly
    // once; replicas of an input appear k consecutive times
    let m = orig_of.len();
    let mut nodes: Vec<Node> = Vec::with_capacity(m);
    for &orig in &orig_of {
        match g.node(orig).kind {
            NodeKind::Input { value } => {
                nodes.push(Node { kind: NodeKind::Input { value }, fanout: Vec::new() });
            }
            NodeKind::Operation { op, src } => {
                let mut new_src = [0u32; 2];
                for (slot, s) in new_src.iter_mut().enumerate().take(op.arity()) {
                    *s = *edge_src
                        .get(&(orig, slot as u8))
                        .unwrap_or(&compiled_of[src[slot] as usize]);
                }
                if op.arity() == 1 {
                    new_src[1] = new_src[0];
                }
                nodes.push(Node { kind: NodeKind::Operation { op, src: new_src }, fanout: Vec::new() });
            }
        }
    }

    // rebuild fanout from the remapped operand edges (destination-order
    // iteration keeps the derivation deterministic)
    for i in 0..m {
        if let NodeKind::Operation { op, src } = nodes[i].kind {
            for (slot, &s) in src[..op.arity()].iter().enumerate() {
                nodes[s as usize].fanout.push((i as u32, slot as u8));
            }
        }
    }

    Some((
        DataflowGraph::from_raw_nodes(nodes),
        NodeMap { orig_len: n, compiled_of, orig_of },
        split_count,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Op;
    use crate::passes::verify::graph_diagnostics;

    fn wide_graph(fanout: usize) -> DataflowGraph {
        let mut g = DataflowGraph::new();
        let hot = g.add_input(3.0);
        let other = g.add_input(4.0);
        for _ in 0..fanout {
            g.op(Op::Add, &[hot, other]);
        }
        g
    }

    #[test]
    fn below_threshold_is_untouched() {
        assert!(run(&wide_graph(FANOUT_THRESHOLD)).is_none());
    }

    #[test]
    fn splits_into_bounded_replicas() {
        let g = wide_graph(150);
        let (g2, map, split) = run(&g).expect("150 > 64");
        assert_eq!(split, 1);
        // ceil(150/64) = 3 replicas of the hot input (2 extra nodes)
        assert_eq!(g2.len(), g.len() + 2);
        assert_eq!(map.orig_of[..4], [0, 0, 0, 1]);
        assert_eq!(map.compiled_of[0], 0);
        for i in 0..g2.len() {
            assert!(
                g2.node(i as u32).fanout.len() <= FANOUT_THRESHOLD,
                "node {i}: fanout {}",
                g2.node(i as u32).fanout.len()
            );
        }
        // the rewrite is itself verify-clean and value-preserving
        assert!(graph_diagnostics(&g2).is_empty(), "{:?}", graph_diagnostics(&g2));
        let (before, after) = (g.evaluate(), g2.evaluate());
        for orig in 0..g.len() {
            assert_eq!(after[map.compiled_of[orig] as usize], before[orig], "node {orig}");
        }
    }

    #[test]
    fn operation_fanout_is_left_alone() {
        // only *inputs* replicate: a hot interior node stays whole
        let mut g = DataflowGraph::new();
        let a = g.add_input(1.0);
        let hot = g.op(Op::Neg, &[a]);
        let pad = g.add_input(2.0);
        for _ in 0..150 {
            g.op(Op::Mul, &[hot, pad]);
        }
        // `pad` crosses the threshold too, so the pass does run
        let (g2, map, split) = run(&g).unwrap();
        assert_eq!(split, 1);
        let hot2 = map.compiled_of[hot as usize];
        assert_eq!(g2.node(hot2).fanout.len(), 150);
    }
}
