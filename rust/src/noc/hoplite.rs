//! Single Hoplite router: combinational switch function.
//!
//! Ports: `W` input (X ring), `N` input (Y ring), PE injection; `E` output
//! (X ring), `S` output (Y ring), PE eject. Dimension-ordered (X then Y)
//! with deflection:
//!
//! * Y-ring traffic (from `N`) has highest priority — it continues south
//!   or ejects; it never deflects.
//! * X-ring traffic (from `W`) turns south / ejects when it reaches its
//!   destination column; if it loses the port to Y-ring traffic it
//!   **deflects east** and circles the X torus again.
//! * PE injection has lowest priority and only proceeds if its first-hop
//!   port is free (otherwise the PE stalls — backpressure).
//!
//! Every packet carries its inject cycle as a [`TaggedPacket`] sideband
//! the switch threads through unchanged — the network computes delivery
//! latency from the tag on eject. (Structurally identical packets are
//! common — same destination node, same payload — so recovering the
//! birth cycle by packet equality is ambiguous; the tag is not.)
//!
//! This is the austere bufferless arbitration that lets the FPGA router
//! cost 130 ALMs (Table I footnote).

use super::Packet;

/// A packet plus the fabric cycle it was injected on.
pub type TaggedPacket = (Packet, u64);

/// Inputs sampled by a router at the start of a cycle.
#[derive(Debug, Clone, Copy, Default)]
pub struct RouterIn {
    pub west: Option<TaggedPacket>,
    pub north: Option<TaggedPacket>,
    pub inject: Option<TaggedPacket>,
}

/// Outputs driven by a router at the end of a cycle.
#[derive(Debug, Clone, Copy, Default)]
pub struct RouterOut {
    pub east: Option<TaggedPacket>,
    pub south: Option<TaggedPacket>,
    pub eject: Option<TaggedPacket>,
    /// true iff `inject` was accepted this cycle
    pub inject_ok: bool,
    /// a W-input packet lost arbitration and went east past its turn
    pub deflected: bool,
}

/// Route one cycle at router (x, y).
pub fn route(x: u8, y: u8, i: RouterIn) -> RouterOut {
    let mut o = RouterOut::default();

    // 1. Y-ring traffic: continue south or eject. Never deflects.
    if let Some((p, b)) = i.north {
        debug_assert_eq!(p.dest_x, x, "packet on Y ring in wrong column");
        if p.dest_y == y {
            o.eject = Some((p, b));
        } else {
            o.south = Some((p, b));
        }
    }

    // 2. X-ring traffic.
    if let Some((p, b)) = i.west {
        if p.dest_x == x {
            if p.dest_y == y {
                // at destination: eject if port free, else deflect east
                if o.eject.is_none() {
                    o.eject = Some((p, b));
                } else {
                    o.east = Some((p, b));
                    o.deflected = true;
                }
            } else {
                // turn south if port free, else deflect east
                if o.south.is_none() {
                    o.south = Some((p, b));
                } else {
                    o.east = Some((p, b));
                    o.deflected = true;
                }
            }
        } else {
            o.east = Some((p, b));
        }
    }

    // 3. PE injection: lowest priority, needs its first-hop port free.
    if let Some((p, b)) = i.inject {
        if p.dest_x == x && p.dest_y == y {
            // local loopback delivery via the eject port
            if o.eject.is_none() {
                o.eject = Some((p, b));
                o.inject_ok = true;
            }
        } else if p.dest_x == x {
            if o.south.is_none() {
                o.south = Some((p, b));
                o.inject_ok = true;
            }
        } else if o.east.is_none() {
            o.east = Some((p, b));
            o.inject_ok = true;
        }
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pkt(x: u8, y: u8) -> Packet {
        Packet {
            dest_x: x,
            dest_y: y,
            local_idx: 0,
            slot: 0,
            payload: 1.0,
        }
    }

    /// Tag a packet with a birth cycle of 0 (the tests only check
    /// switching; the latency tag rides along unchanged).
    fn t(p: Packet) -> TaggedPacket {
        (p, 0)
    }

    #[test]
    fn x_traffic_continues_east() {
        let o = route(2, 2, RouterIn { west: Some(t(pkt(5, 2))), ..Default::default() });
        assert_eq!(o.east, Some(t(pkt(5, 2))));
        assert!(o.south.is_none() && o.eject.is_none());
    }

    #[test]
    fn x_traffic_turns_south_at_column() {
        let o = route(5, 2, RouterIn { west: Some(t(pkt(5, 7))), ..Default::default() });
        assert_eq!(o.south, Some(t(pkt(5, 7))));
    }

    #[test]
    fn y_traffic_ejects_at_destination() {
        let o = route(5, 7, RouterIn { north: Some(t(pkt(5, 7))), ..Default::default() });
        assert_eq!(o.eject, Some(t(pkt(5, 7))));
        assert!(o.south.is_none());
    }

    #[test]
    fn turn_conflict_deflects_x_traffic() {
        let o = route(
            5,
            2,
            RouterIn {
                west: Some(t(pkt(5, 7))),  // wants S
                north: Some(t(pkt(5, 9))), // continuing S, has priority
                ..Default::default()
            },
        );
        assert_eq!(o.south, Some(t(pkt(5, 9))));
        assert_eq!(o.east, Some(t(pkt(5, 7))), "loser deflects east");
        assert!(o.deflected);
    }

    #[test]
    fn eject_conflict_deflects_x_traffic() {
        let o = route(
            5,
            7,
            RouterIn {
                west: Some(t(pkt(5, 7))),
                north: Some(t(pkt(5, 7))),
                ..Default::default()
            },
        );
        assert_eq!(o.eject, Some(t(pkt(5, 7))));
        assert!(o.deflected && o.east.is_some());
    }

    /// The latency tag must follow each packet through arbitration:
    /// two identical packets with different birth cycles keep their own
    /// tags on whichever ports they win (the misattribution the old
    /// equality-matching birth recovery got wrong).
    #[test]
    fn tags_follow_packets_through_arbitration() {
        let o = route(
            5,
            7,
            RouterIn {
                west: Some((pkt(5, 7), 31)),
                north: Some((pkt(5, 7), 40)),
                ..Default::default()
            },
        );
        assert_eq!(o.eject, Some((pkt(5, 7), 40)), "N wins eject, keeps its tag");
        assert_eq!(o.east, Some((pkt(5, 7), 31)), "W deflects, keeps its tag");
    }

    #[test]
    fn inject_blocked_when_port_busy() {
        // injection wants E but W-traffic holds it
        let o = route(
            2,
            2,
            RouterIn {
                west: Some(t(pkt(9, 2))),
                inject: Some(t(pkt(4, 4))),
                ..Default::default()
            },
        );
        assert!(!o.inject_ok);
        assert_eq!(o.east, Some(t(pkt(9, 2))));
    }

    #[test]
    fn inject_takes_free_south() {
        let o = route(
            2,
            2,
            RouterIn {
                inject: Some(t(pkt(2, 5))),
                ..Default::default()
            },
        );
        assert!(o.inject_ok);
        assert_eq!(o.south, Some(t(pkt(2, 5))));
    }

    #[test]
    fn self_delivery_uses_eject() {
        let o = route(2, 2, RouterIn { inject: Some(t(pkt(2, 2))), ..Default::default() });
        assert!(o.inject_ok);
        assert_eq!(o.eject, Some(t(pkt(2, 2))));
    }

    #[test]
    fn self_delivery_blocked_by_arriving_packet() {
        let o = route(
            2,
            2,
            RouterIn {
                north: Some(t(pkt(2, 2))),
                inject: Some(t(pkt(2, 2))),
                ..Default::default()
            },
        );
        assert!(!o.inject_ok, "eject port busy; PE must retry");
    }

    #[test]
    fn y_ring_never_deflects() {
        // even with W wanting the same S port
        let o = route(
            1,
            1,
            RouterIn {
                north: Some(t(pkt(1, 3))),
                west: Some(t(pkt(1, 3))),
                ..Default::default()
            },
        );
        assert_eq!(o.south, Some(t(pkt(1, 3))));
        assert!(o.deflected);
    }
}
