//! Single Hoplite router: combinational switch function.
//!
//! Ports: `W` input (X ring), `N` input (Y ring), PE injection; `E` output
//! (X ring), `S` output (Y ring), PE eject. Dimension-ordered (X then Y)
//! with deflection:
//!
//! * Y-ring traffic (from `N`) has highest priority — it continues south
//!   or ejects; it never deflects.
//! * X-ring traffic (from `W`) turns south / ejects when it reaches its
//!   destination column; if it loses the port to Y-ring traffic it
//!   **deflects east** and circles the X torus again.
//! * PE injection has lowest priority and only proceeds if its first-hop
//!   port is free (otherwise the PE stalls — backpressure).
//!
//! This is the austere bufferless arbitration that lets the FPGA router
//! cost 130 ALMs (Table I footnote).

use super::Packet;

/// Inputs sampled by a router at the start of a cycle.
#[derive(Debug, Clone, Copy, Default)]
pub struct RouterIn {
    pub west: Option<Packet>,
    pub north: Option<Packet>,
    pub inject: Option<Packet>,
}

/// Outputs driven by a router at the end of a cycle.
#[derive(Debug, Clone, Copy, Default)]
pub struct RouterOut {
    pub east: Option<Packet>,
    pub south: Option<Packet>,
    pub eject: Option<Packet>,
    /// true iff `inject` was accepted this cycle
    pub inject_ok: bool,
    /// a W-input packet lost arbitration and went east past its turn
    pub deflected: bool,
}

/// Route one cycle at router (x, y).
pub fn route(x: u8, y: u8, i: RouterIn) -> RouterOut {
    let mut o = RouterOut::default();

    // 1. Y-ring traffic: continue south or eject. Never deflects.
    if let Some(p) = i.north {
        debug_assert_eq!(p.dest_x, x, "packet on Y ring in wrong column");
        if p.dest_y == y {
            o.eject = Some(p);
        } else {
            o.south = Some(p);
        }
    }

    // 2. X-ring traffic.
    if let Some(p) = i.west {
        if p.dest_x == x {
            if p.dest_y == y {
                // at destination: eject if port free, else deflect east
                if o.eject.is_none() {
                    o.eject = Some(p);
                } else {
                    o.east = Some(p);
                    o.deflected = true;
                }
            } else {
                // turn south if port free, else deflect east
                if o.south.is_none() {
                    o.south = Some(p);
                } else {
                    o.east = Some(p);
                    o.deflected = true;
                }
            }
        } else {
            o.east = Some(p);
        }
    }

    // 3. PE injection: lowest priority, needs its first-hop port free.
    if let Some(p) = i.inject {
        if p.dest_x == x && p.dest_y == y {
            // local loopback delivery via the eject port
            if o.eject.is_none() {
                o.eject = Some(p);
                o.inject_ok = true;
            }
        } else if p.dest_x == x {
            if o.south.is_none() {
                o.south = Some(p);
                o.inject_ok = true;
            }
        } else if o.east.is_none() {
            o.east = Some(p);
            o.inject_ok = true;
        }
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pkt(x: u8, y: u8) -> Packet {
        Packet {
            dest_x: x,
            dest_y: y,
            local_idx: 0,
            slot: 0,
            payload: 1.0,
        }
    }

    #[test]
    fn x_traffic_continues_east() {
        let o = route(2, 2, RouterIn { west: Some(pkt(5, 2)), ..Default::default() });
        assert_eq!(o.east, Some(pkt(5, 2)));
        assert!(o.south.is_none() && o.eject.is_none());
    }

    #[test]
    fn x_traffic_turns_south_at_column() {
        let o = route(5, 2, RouterIn { west: Some(pkt(5, 7)), ..Default::default() });
        assert_eq!(o.south, Some(pkt(5, 7)));
    }

    #[test]
    fn y_traffic_ejects_at_destination() {
        let o = route(5, 7, RouterIn { north: Some(pkt(5, 7)), ..Default::default() });
        assert_eq!(o.eject, Some(pkt(5, 7)));
        assert!(o.south.is_none());
    }

    #[test]
    fn turn_conflict_deflects_x_traffic() {
        let o = route(
            5,
            2,
            RouterIn {
                west: Some(pkt(5, 7)),   // wants S
                north: Some(pkt(5, 9)),  // continuing S, has priority
                ..Default::default()
            },
        );
        assert_eq!(o.south, Some(pkt(5, 9)));
        assert_eq!(o.east, Some(pkt(5, 7)), "loser deflects east");
        assert!(o.deflected);
    }

    #[test]
    fn eject_conflict_deflects_x_traffic() {
        let o = route(
            5,
            7,
            RouterIn {
                west: Some(pkt(5, 7)),
                north: Some(pkt(5, 7)),
                ..Default::default()
            },
        );
        assert_eq!(o.eject, Some(pkt(5, 7)));
        assert!(o.deflected && o.east.is_some());
    }

    #[test]
    fn inject_blocked_when_port_busy() {
        // injection wants E but W-traffic holds it
        let o = route(
            2,
            2,
            RouterIn {
                west: Some(pkt(9, 2)),
                inject: Some(pkt(4, 4)),
                ..Default::default()
            },
        );
        assert!(!o.inject_ok);
        assert_eq!(o.east, Some(pkt(9, 2)));
    }

    #[test]
    fn inject_takes_free_south() {
        let o = route(
            2,
            2,
            RouterIn {
                inject: Some(pkt(2, 5)),
                ..Default::default()
            },
        );
        assert!(o.inject_ok);
        assert_eq!(o.south, Some(pkt(2, 5)));
    }

    #[test]
    fn self_delivery_uses_eject() {
        let o = route(2, 2, RouterIn { inject: Some(pkt(2, 2)), ..Default::default() });
        assert!(o.inject_ok);
        assert_eq!(o.eject, Some(pkt(2, 2)));
    }

    #[test]
    fn self_delivery_blocked_by_arriving_packet() {
        let o = route(
            2,
            2,
            RouterIn {
                north: Some(pkt(2, 2)),
                inject: Some(pkt(2, 2)),
                ..Default::default()
            },
        );
        assert!(!o.inject_ok, "eject port busy; PE must retry");
    }

    #[test]
    fn y_ring_never_deflects() {
        // even with W wanting the same S port
        let o = route(
            1,
            1,
            RouterIn {
                north: Some(pkt(1, 3)),
                west: Some(pkt(1, 3)),
                ..Default::default()
            },
        );
        assert_eq!(o.south, Some(pkt(1, 3)));
        assert!(o.deflected);
    }
}
