//! Hoplite NoC model (Kapre & Gray, FPL'15) — the overlay interconnect.
//!
//! PEs and routers sit on a unidirectional 2-D torus. Packets use
//! dimension-ordered routing (X then Y) with *deflection*: a packet that
//! loses arbitration for the south port keeps circling the X ring instead
//! of being buffered — Hoplite routers are bufferless (130 ALMs / 350
//! registers each, Table I footnote).
//!
//! Width check: the paper's links are 56 b. [`packet::Packet::pack56`]
//! proves our header + f32 payload fits.

mod hoplite;
mod network;
mod packet;

pub use hoplite::{route, RouterIn, RouterOut, TaggedPacket};
pub use network::{Network, NetworkStats, StepResult};
pub use packet::{Packet, MAX_DIM, MAX_LOCAL_NODES};
