//! The full torus: routers + link registers, stepped one cycle at a time.
//!
//! Link registers hold packets in flight: `x_link[(x,y)]` is the register
//! on the E output of router (x,y) feeding the W input of router
//! ((x+1)%w, y); `y_link[(x,y)]` feeds ((x, (y+1)%h)). All routers switch
//! simultaneously (double-buffered update).
//!
//! Perf note (EXPERIMENTS.md §Perf): `step` is the simulator's hottest
//! loop after the PE scan; all per-cycle state (`next_*` link buffers and
//! the [`StepResult`]) is preallocated and swapped/reused — zero
//! allocation at steady state.

use super::hoplite::{route, RouterIn};
use super::Packet;

/// Cumulative network statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NetworkStats {
    pub injected: u64,
    pub delivered: u64,
    pub deflections: u64,
    pub inject_stalls: u64,
    /// sum over delivered packets of (deliver cycle − inject cycle)
    pub total_latency: u64,
    pub max_latency: u64,
}

/// Result of one network cycle (buffers owned by [`Network`], reused).
#[derive(Debug, Clone, Default)]
pub struct StepResult {
    /// packet delivered to each PE this cycle (index = y*w + x)
    pub ejected: Vec<Option<Packet>>,
    /// per-PE: was this PE's injection request accepted?
    pub inject_ok: Vec<bool>,
}

/// The Hoplite torus.
pub struct Network {
    pub w: usize,
    pub h: usize,
    x_link: Vec<Option<(Packet, u64)>>, // (packet, inject cycle)
    y_link: Vec<Option<(Packet, u64)>>,
    // double buffers swapped with the live links each cycle
    x_next: Vec<Option<(Packet, u64)>>,
    y_next: Vec<Option<(Packet, u64)>>,
    out: StepResult,
    in_flight: usize,
    cycle: u64,
    pub stats: NetworkStats,
}

impl Network {
    pub fn new(w: usize, h: usize) -> Self {
        assert!(w >= 1 && h >= 1 && w <= 32 && h <= 32);
        let n = w * h;
        Self {
            w,
            h,
            x_link: vec![None; n],
            y_link: vec![None; n],
            x_next: vec![None; n],
            y_next: vec![None; n],
            out: StepResult {
                ejected: vec![None; n],
                inject_ok: vec![false; n],
            },
            in_flight: 0,
            cycle: 0,
            stats: NetworkStats::default(),
        }
    }

    #[inline]
    fn idx(&self, x: usize, y: usize) -> usize {
        y * self.w + x
    }

    /// Packets currently on links. Deflection routing makes in-flight
    /// cycles irreducible (a packet's path depends on every arbitration
    /// it meets), so the skip-ahead engine only jumps the clock while
    /// this is zero and falls back to cycle-accurate stepping otherwise.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    pub fn is_empty(&self) -> bool {
        self.in_flight == 0
    }

    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Advance one cycle. `inject[pe]` is each PE's injection request
    /// (at most one per cycle, per the paper's packet-generation rate).
    /// The returned result borrows internal buffers valid until the next
    /// call.
    pub fn step(&mut self, inject: &[Option<Packet>]) -> &StepResult {
        debug_assert_eq!(inject.len(), self.w * self.h);
        for slot in self.x_next.iter_mut() {
            *slot = None;
        }
        for slot in self.y_next.iter_mut() {
            *slot = None;
        }
        for slot in self.out.ejected.iter_mut() {
            *slot = None;
        }
        for slot in self.out.inject_ok.iter_mut() {
            *slot = false;
        }
        let mut in_flight = 0usize;

        for y in 0..self.h {
            for x in 0..self.w {
                let me = self.idx(x, y);
                // W input of (x,y) = x_link register of the router west of us.
                let west_src = self.idx((x + self.w - 1) % self.w, y);
                let north_src = self.idx(x, (y + self.h - 1) % self.h);
                let w_in = self.x_link[west_src];
                let n_in = self.y_link[north_src];
                // fast path: idle router (most routers, most cycles)
                if w_in.is_none() && n_in.is_none() && inject[me].is_none() {
                    continue;
                }
                let io = RouterIn {
                    west: w_in.map(|(p, _)| p),
                    north: n_in.map(|(p, _)| p),
                    inject: inject[me],
                };
                let o = route(x as u8, y as u8, io);

                // reconstruct birth cycles for output packets
                let birth_of = |p: &Packet| -> u64 {
                    if let Some((q, b)) = w_in {
                        if q == *p {
                            return b;
                        }
                    }
                    if let Some((q, b)) = n_in {
                        if q == *p {
                            return b;
                        }
                    }
                    self.cycle // freshly injected
                };

                if let Some(p) = o.east {
                    self.x_next[me] = Some((p, birth_of(&p)));
                    in_flight += 1;
                }
                if let Some(p) = o.south {
                    self.y_next[me] = Some((p, birth_of(&p)));
                    in_flight += 1;
                }
                if let Some(p) = o.eject {
                    let b = birth_of(&p);
                    let lat = self.cycle - b;
                    self.stats.delivered += 1;
                    self.stats.total_latency += lat;
                    self.stats.max_latency = self.stats.max_latency.max(lat);
                    self.out.ejected[me] = Some(p);
                }
                if o.deflected {
                    self.stats.deflections += 1;
                }
                if io.inject.is_some() {
                    if o.inject_ok {
                        self.stats.injected += 1;
                        self.out.inject_ok[me] = true;
                    } else {
                        self.stats.inject_stalls += 1;
                    }
                }
            }
        }

        std::mem::swap(&mut self.x_link, &mut self.x_next);
        std::mem::swap(&mut self.y_link, &mut self.y_next);
        self.in_flight = in_flight;
        self.cycle += 1;
        &self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pkt(x: u8, y: u8, tag: u16) -> Packet {
        Packet {
            dest_x: x,
            dest_y: y,
            local_idx: tag,
            slot: 0,
            payload: tag as f32,
        }
    }

    /// drive the network until `want` packets are delivered or timeout
    fn drain(net: &mut Network, mut pending: Vec<(usize, Packet)>, want: usize) -> Vec<(usize, Packet)> {
        let n = net.w * net.h;
        let mut delivered = Vec::new();
        for _ in 0..10_000 {
            let mut inject: Vec<Option<Packet>> = vec![None; n];
            for &(pe, p) in pending.iter() {
                if inject[pe].is_none() {
                    inject[pe] = Some(p);
                }
            }
            let res = net.step(&inject);
            // remove accepted from pending (first queued per PE)
            let mut granted = vec![false; n];
            let inject_ok = res.inject_ok.clone();
            for (pe, e) in res.ejected.iter().enumerate() {
                if let Some(p) = e {
                    delivered.push((pe, *p));
                }
            }
            let mut still = Vec::new();
            for (pe, p) in pending {
                if !granted[pe] && inject_ok[pe] && inject[pe] == Some(p) {
                    granted[pe] = true;
                } else {
                    still.push((pe, p));
                }
            }
            pending = still;
            if delivered.len() >= want && net.is_empty() && pending.is_empty() {
                break;
            }
        }
        delivered
    }

    #[test]
    fn single_packet_dor_delivery() {
        let mut net = Network::new(4, 4);
        // from PE (0,0) to (2,3): 2 hops east + 3 hops south + eject
        let p = pkt(2, 3, 7);
        let delivered = drain(&mut net, vec![(0, p)], 1);
        assert_eq!(delivered, vec![(3 * 4 + 2, p)]);
        assert_eq!(net.stats.delivered, 1);
        assert_eq!(net.stats.deflections, 0);
    }

    #[test]
    fn self_delivery_works() {
        let mut net = Network::new(3, 3);
        let p = pkt(1, 1, 9);
        let pe = 1 * 3 + 1;
        let delivered = drain(&mut net, vec![(pe, p)], 1);
        assert_eq!(delivered, vec![(pe, p)]);
    }

    #[test]
    fn torus_wraparound() {
        let mut net = Network::new(4, 4);
        // (3,3) -> (0,0): wraps both dimensions
        let p = pkt(0, 0, 3);
        let delivered = drain(&mut net, vec![(3 * 4 + 3, p)], 1);
        assert_eq!(delivered, vec![(0, p)]);
    }

    #[test]
    fn all_to_one_hotspot_delivers_everything() {
        let mut net = Network::new(4, 4);
        let n = 16;
        let mut pending = Vec::new();
        for pe in 0..n {
            if pe != 5 {
                pending.push((pe, pkt(1, 1, pe as u16)));
            }
        }
        let delivered = drain(&mut net, pending, 15);
        assert_eq!(delivered.len(), 15, "every packet must arrive");
        let mut tags: Vec<u16> = delivered.iter().map(|&(_, p)| p.local_idx).collect();
        tags.sort_unstable();
        let want: Vec<u16> = (0..16u16).filter(|&t| t != 5).collect();
        assert_eq!(tags, want, "no loss, no duplication");
        for (pe, _) in delivered {
            assert_eq!(pe, 1 * 4 + 1);
        }
    }

    #[test]
    fn random_permutation_traffic() {
        let mut net = Network::new(8, 8);
        let n = 64;
        let mut pending = Vec::new();
        for pe in 0..n {
            let dest = (pe * 37 + 11) % n; // fixed permutation
            pending.push((pe, pkt((dest % 8) as u8, (dest / 8) as u8, pe as u16)));
        }
        let delivered = drain(&mut net, pending, n);
        assert_eq!(delivered.len(), n);
        assert!(net.is_empty());
    }

    #[test]
    fn latency_stats_accumulate() {
        let mut net = Network::new(4, 4);
        let delivered = drain(&mut net, vec![(0, pkt(2, 3, 0))], 1);
        assert_eq!(delivered.len(), 1);
        // 2 east hops + turn + 3 south hops: latency >= 5 cycles
        assert!(net.stats.max_latency >= 5, "{:?}", net.stats);
        assert_eq!(net.stats.total_latency, net.stats.max_latency);
    }

    #[test]
    fn one_by_one_torus_self_loop() {
        let mut net = Network::new(1, 1);
        let p = pkt(0, 0, 1);
        let delivered = drain(&mut net, vec![(0, p)], 1);
        assert_eq!(delivered, vec![(0, p)]);
    }

    #[test]
    fn in_flight_tracking() {
        let mut net = Network::new(4, 1);
        let mut inject = vec![None; 4];
        inject[0] = Some(pkt(2, 0, 0));
        let ok = net.step(&inject).inject_ok[0];
        assert!(ok);
        assert_eq!(net.in_flight(), 1);
        net.step(&vec![None; 4]);
        let got = net.step(&vec![None; 4]).ejected[2];
        // after 3 cycles: 2 hops + eject
        assert!(got.is_some());
        assert!(net.is_empty());
    }

    #[test]
    fn step_result_buffers_reset_each_cycle() {
        let mut net = Network::new(2, 2);
        let mut inject = vec![None; 4];
        inject[0] = Some(pkt(0, 0, 1)); // self delivery, cycle 0
        let r = net.step(&inject);
        assert!(r.ejected[0].is_some());
        let r = net.step(&vec![None; 4]);
        assert!(r.ejected[0].is_none(), "stale ejects must clear");
        assert!(!r.inject_ok[0]);
    }
}
