//! The full torus: routers + link registers, stepped one cycle at a time.
//!
//! Link registers hold packets in flight: `x_link[(x,y)]` is the register
//! on the E output of router (x,y) feeding the W input of router
//! ((x+1)%w, y); `y_link[(x,y)]` feeds ((x, (y+1)%h)). All routers switch
//! simultaneously (double-buffered update).
//!
//! Perf note (DESIGN.md §7): `step` is activity-proportional. Only
//! routers that can do anything this cycle — routers fed by an occupied
//! link register, plus routers with an injection request — are visited;
//! everything else costs nothing. The occupied-slot lists (`x_occ` /
//! `y_occ`) are maintained incrementally as outputs are written, and the
//! per-cycle [`StepResult`] buffers are cleared lazily (only the slots
//! written last cycle), so an idle region of the torus is never touched.
//! All buffers are preallocated — zero allocation at steady state.
//!
//! Every in-flight packet carries its inject cycle as a
//! [`TaggedPacket`]; delivery latency is the tag delta at eject.
//! (Recovering the birth by structural packet equality — the old scheme
//! — silently swapped the birth cycles of identical-payload packets.)

use super::hoplite::{route, RouterIn, TaggedPacket};
use super::Packet;

/// Cumulative network statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NetworkStats {
    pub injected: u64,
    pub delivered: u64,
    pub deflections: u64,
    pub inject_stalls: u64,
    /// sum over delivered packets of (deliver cycle − inject cycle)
    pub total_latency: u64,
    pub max_latency: u64,
}

impl NetworkStats {
    /// JSON object with every counter (the service response format —
    /// [`crate::sim::SimStats::to_json_value`] nests this under `net`).
    pub fn to_json_value(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("injected".to_string(), Json::Num(self.injected as f64));
        m.insert("delivered".to_string(), Json::Num(self.delivered as f64));
        m.insert("deflections".to_string(), Json::Num(self.deflections as f64));
        m.insert("inject_stalls".to_string(), Json::Num(self.inject_stalls as f64));
        m.insert("total_latency".to_string(), Json::Num(self.total_latency as f64));
        m.insert("max_latency".to_string(), Json::Num(self.max_latency as f64));
        Json::Obj(m)
    }

    /// Strict inverse of [`NetworkStats::to_json_value`]: every key
    /// required to be a counter we know, unknown keys rejected.
    pub fn from_json_value(j: &crate::util::json::Json) -> Result<Self, String> {
        let obj = j.as_obj().ok_or("net: expected object")?;
        let mut s = NetworkStats::default();
        for (key, v) in obj {
            let n = v
                .as_u64()
                .ok_or_else(|| format!("net.{key}: expected non-negative integer"))?;
            match key.as_str() {
                "injected" => s.injected = n,
                "delivered" => s.delivered = n,
                "deflections" => s.deflections = n,
                "inject_stalls" => s.inject_stalls = n,
                "total_latency" => s.total_latency = n,
                "max_latency" => s.max_latency = n,
                other => return Err(format!("unknown net counter '{other}'")),
            }
        }
        Ok(s)
    }

    /// Component-wise merge of per-fabric stats — how sharded execution
    /// ([`crate::shard`]) folds N fabrics' NoC counters into one
    /// [`crate::sim::SimStats`]: counters sum, `max_latency` takes the
    /// max. Identity for a single input (the sharded N=1 bit-identity
    /// guarantee leans on this).
    pub fn merged<I: IntoIterator<Item = NetworkStats>>(stats: I) -> NetworkStats {
        stats.into_iter().fold(NetworkStats::default(), |mut acc, s| {
            acc.injected += s.injected;
            acc.delivered += s.delivered;
            acc.deflections += s.deflections;
            acc.inject_stalls += s.inject_stalls;
            acc.total_latency += s.total_latency;
            acc.max_latency = acc.max_latency.max(s.max_latency);
            acc
        })
    }
}

/// Result of one network cycle (buffers owned by [`Network`], reused).
#[derive(Debug, Clone, Default)]
pub struct StepResult {
    /// packet delivered to each PE this cycle (index = y*w + x)
    pub ejected: Vec<Option<Packet>>,
    /// per-PE: was this PE's injection request accepted?
    pub inject_ok: Vec<bool>,
    /// PEs with a delivery in `ejected` this cycle (sparse mirror, so
    /// consumers need not scan the dense buffer)
    pub ejected_pes: Vec<u32>,
}

/// The Hoplite torus.
pub struct Network {
    pub w: usize,
    pub h: usize,
    x_link: Vec<Option<TaggedPacket>>,
    y_link: Vec<Option<TaggedPacket>>,
    // double buffers swapped with the live links each cycle
    x_next: Vec<Option<TaggedPacket>>,
    y_next: Vec<Option<TaggedPacket>>,
    /// occupied slots of `x_link` / `y_link` — the seed of the
    /// active-router set, swapped with `*_occ_next` like the links
    x_occ: Vec<u32>,
    y_occ: Vec<u32>,
    x_occ_next: Vec<u32>,
    y_occ_next: Vec<u32>,
    /// routers visited this cycle (rebuilt each step; `mark` dedupes)
    active: Vec<u32>,
    mark: Vec<bool>,
    // Precomputed torus topology (built once in `new`): the step loop
    // performs zero div/mod per packet per cycle.
    /// router fed by `x_link[s]` (the E neighbor of router `s`)
    east_of: Vec<u32>,
    /// router fed by `y_link[s]` (the S neighbor of router `s`)
    south_of: Vec<u32>,
    /// link register feeding router `me`'s W input
    west_src: Vec<u32>,
    /// link register feeding router `me`'s N input
    north_src: Vec<u32>,
    /// router `me`'s torus coordinates
    xy: Vec<(u8, u8)>,
    /// `out.inject_ok` slots set last cycle (lazy clearing)
    granted: Vec<u32>,
    /// scratch injector list for the dense-inject [`Network::step`]
    scan_buf: Vec<u32>,
    out: StepResult,
    in_flight: usize,
    cycle: u64,
    pub stats: NetworkStats,
    /// per-router packets switched (link arrivals + accepted injections),
    /// index = y*w + x; folded into the active-router walk, so idle
    /// routers cost nothing (telemetry heatmaps, DESIGN.md §11)
    router_traffic: Vec<u64>,
    /// per-router deflection count, same indexing
    router_deflections: Vec<u64>,
}

impl Network {
    pub fn new(w: usize, h: usize) -> Self {
        assert!(w >= 1 && h >= 1 && w <= 32 && h <= 32);
        let n = w * h;
        let mut east_of = Vec::with_capacity(n);
        let mut south_of = Vec::with_capacity(n);
        let mut west_src = Vec::with_capacity(n);
        let mut north_src = Vec::with_capacity(n);
        let mut xy = Vec::with_capacity(n);
        for me in 0..n {
            let x = me % w;
            let y = me / w;
            east_of.push((y * w + (x + 1) % w) as u32);
            south_of.push((((y + 1) % h) * w + x) as u32);
            west_src.push((y * w + (x + w - 1) % w) as u32);
            north_src.push((((y + h - 1) % h) * w + x) as u32);
            xy.push((x as u8, y as u8));
        }
        Self {
            w,
            h,
            x_link: vec![None; n],
            y_link: vec![None; n],
            x_next: vec![None; n],
            y_next: vec![None; n],
            x_occ: Vec::new(),
            y_occ: Vec::new(),
            x_occ_next: Vec::new(),
            y_occ_next: Vec::new(),
            active: Vec::new(),
            mark: vec![false; n],
            east_of,
            south_of,
            west_src,
            north_src,
            xy,
            granted: Vec::new(),
            scan_buf: Vec::new(),
            out: StepResult {
                ejected: vec![None; n],
                inject_ok: vec![false; n],
                ejected_pes: Vec::new(),
            },
            in_flight: 0,
            cycle: 0,
            stats: NetworkStats::default(),
            router_traffic: vec![0; n],
            router_deflections: vec![0; n],
        }
    }

    /// Per-router switched-packet counts (index = y*w + x).
    pub fn router_traffic(&self) -> &[u64] {
        &self.router_traffic
    }

    /// Per-router deflection counts (index = y*w + x); sums to
    /// `stats.deflections`.
    pub fn router_deflections(&self) -> &[u64] {
        &self.router_deflections
    }

    /// Packets currently on links. Deflection routing makes in-flight
    /// cycles irreducible (a packet's path depends on every arbitration
    /// it meets), so the skip-ahead engine only jumps the clock while
    /// this is zero and falls back to cycle-accurate stepping otherwise.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    pub fn is_empty(&self) -> bool {
        self.in_flight == 0
    }

    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Advance one cycle. `inject[pe]` is each PE's injection request
    /// (at most one per cycle, per the paper's packet-generation rate).
    /// The returned result borrows internal buffers valid until the next
    /// call.
    ///
    /// This convenience form scans `inject` for requests; hot callers
    /// that already know their injectors (the simulator's active-PE
    /// worklist) use [`Network::step_sparse`] and skip the scan.
    pub fn step(&mut self, inject: &[Option<Packet>]) -> &StepResult {
        let mut injectors = std::mem::take(&mut self.scan_buf);
        injectors.clear();
        for (pe, slot) in inject.iter().enumerate() {
            if slot.is_some() {
                injectors.push(pe as u32);
            }
        }
        self.step_sparse(inject, &injectors);
        self.scan_buf = injectors;
        &self.out
    }

    /// [`Network::step`] with the injecting PEs named up front:
    /// `injectors` must list exactly the indices where `inject` is
    /// `Some`. Cost is proportional to packets in flight + injections,
    /// not to the torus size.
    pub fn step_sparse(&mut self, inject: &[Option<Packet>], injectors: &[u32]) -> &StepResult {
        debug_assert_eq!(inject.len(), self.w * self.h);
        debug_assert!(injectors.iter().all(|&pe| inject[pe as usize].is_some()));
        debug_assert_eq!(
            injectors.len(),
            inject.iter().filter(|s| s.is_some()).count(),
            "injectors must name every Some slot of inject"
        );

        // lazily clear last cycle's sparse outputs
        for &pe in &self.out.ejected_pes {
            self.out.ejected[pe as usize] = None;
        }
        self.out.ejected_pes.clear();
        for &pe in &self.granted {
            self.out.inject_ok[pe as usize] = false;
        }
        self.granted.clear();

        // active routers: the ones fed by an occupied link register,
        // plus the injectors. Everyone else switches nothing. Neighbor
        // indices come from the precomputed topology tables — no
        // div/mod per packet.
        debug_assert!(self.active.is_empty());
        for &s in &self.x_occ {
            let me = self.east_of[s as usize] as usize;
            if !self.mark[me] {
                self.mark[me] = true;
                self.active.push(me as u32);
            }
        }
        for &s in &self.y_occ {
            let me = self.south_of[s as usize] as usize;
            if !self.mark[me] {
                self.mark[me] = true;
                self.active.push(me as u32);
            }
        }
        for &pe in injectors {
            let me = pe as usize;
            if !self.mark[me] {
                self.mark[me] = true;
                self.active.push(me as u32);
            }
        }

        for &r in &self.active {
            let me = r as usize;
            let (x, y) = self.xy[me];
            let io = RouterIn {
                west: self.x_link[self.west_src[me] as usize],
                north: self.y_link[self.north_src[me] as usize],
                inject: inject[me].map(|p| (p, self.cycle)),
            };
            let o = route(x, y, io);
            let mut switched = io.west.is_some() as u64 + io.north.is_some() as u64;

            if let Some(t) = o.east {
                self.x_next[me] = Some(t);
                self.x_occ_next.push(me as u32);
            }
            if let Some(t) = o.south {
                self.y_next[me] = Some(t);
                self.y_occ_next.push(me as u32);
            }
            if let Some((p, birth)) = o.eject {
                let lat = self.cycle - birth;
                self.stats.delivered += 1;
                self.stats.total_latency += lat;
                self.stats.max_latency = self.stats.max_latency.max(lat);
                self.out.ejected[me] = Some(p);
                self.out.ejected_pes.push(me as u32);
            }
            if o.deflected {
                self.stats.deflections += 1;
                self.router_deflections[me] += 1;
            }
            if io.inject.is_some() {
                if o.inject_ok {
                    self.stats.injected += 1;
                    self.out.inject_ok[me] = true;
                    self.granted.push(me as u32);
                    switched += 1;
                } else {
                    self.stats.inject_stalls += 1;
                }
            }
            self.router_traffic[me] += switched;
        }

        // reset the dedupe marks and consume the routed link registers
        // (every occupied input link feeds an active router, which
        // always forwards or ejects its packet — bufferless routing)
        for &me in &self.active {
            self.mark[me as usize] = false;
        }
        self.active.clear();
        for &s in &self.x_occ {
            self.x_link[s as usize] = None;
        }
        for &s in &self.y_occ {
            self.y_link[s as usize] = None;
        }
        std::mem::swap(&mut self.x_link, &mut self.x_next);
        std::mem::swap(&mut self.y_link, &mut self.y_next);
        std::mem::swap(&mut self.x_occ, &mut self.x_occ_next);
        std::mem::swap(&mut self.y_occ, &mut self.y_occ_next);
        self.x_occ_next.clear();
        self.y_occ_next.clear();
        self.in_flight = self.x_occ.len() + self.y_occ.len();
        self.cycle += 1;
        &self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pkt(x: u8, y: u8, tag: u16) -> Packet {
        Packet {
            dest_x: x,
            dest_y: y,
            local_idx: tag,
            slot: 0,
            payload: tag as f32,
        }
    }

    /// drive the network until `want` packets are delivered or timeout
    fn drain(net: &mut Network, mut pending: Vec<(usize, Packet)>, want: usize) -> Vec<(usize, Packet)> {
        let n = net.w * net.h;
        let mut delivered = Vec::new();
        for _ in 0..10_000 {
            let mut inject: Vec<Option<Packet>> = vec![None; n];
            for &(pe, p) in pending.iter() {
                if inject[pe].is_none() {
                    inject[pe] = Some(p);
                }
            }
            let res = net.step(&inject);
            // remove accepted from pending (first queued per PE)
            let mut granted = vec![false; n];
            let inject_ok = res.inject_ok.clone();
            for (pe, e) in res.ejected.iter().enumerate() {
                if let Some(p) = e {
                    delivered.push((pe, *p));
                }
            }
            let mut still = Vec::new();
            for (pe, p) in pending {
                if !granted[pe] && inject_ok[pe] && inject[pe] == Some(p) {
                    granted[pe] = true;
                } else {
                    still.push((pe, p));
                }
            }
            pending = still;
            if delivered.len() >= want && net.is_empty() && pending.is_empty() {
                break;
            }
        }
        delivered
    }

    #[test]
    fn single_packet_dor_delivery() {
        let mut net = Network::new(4, 4);
        // from PE (0,0) to (2,3): 2 hops east + 3 hops south + eject
        let p = pkt(2, 3, 7);
        let delivered = drain(&mut net, vec![(0, p)], 1);
        assert_eq!(delivered, vec![(3 * 4 + 2, p)]);
        assert_eq!(net.stats.delivered, 1);
        assert_eq!(net.stats.deflections, 0);
    }

    #[test]
    fn self_delivery_works() {
        let mut net = Network::new(3, 3);
        let p = pkt(1, 1, 9);
        let pe = 1 * 3 + 1;
        let delivered = drain(&mut net, vec![(pe, p)], 1);
        assert_eq!(delivered, vec![(pe, p)]);
    }

    #[test]
    fn torus_wraparound() {
        let mut net = Network::new(4, 4);
        // (3,3) -> (0,0): wraps both dimensions
        let p = pkt(0, 0, 3);
        let delivered = drain(&mut net, vec![(3 * 4 + 3, p)], 1);
        assert_eq!(delivered, vec![(0, p)]);
    }

    #[test]
    fn all_to_one_hotspot_delivers_everything() {
        let mut net = Network::new(4, 4);
        let n = 16;
        let mut pending = Vec::new();
        for pe in 0..n {
            if pe != 5 {
                pending.push((pe, pkt(1, 1, pe as u16)));
            }
        }
        let delivered = drain(&mut net, pending, 15);
        assert_eq!(delivered.len(), 15, "every packet must arrive");
        let mut tags: Vec<u16> = delivered.iter().map(|&(_, p)| p.local_idx).collect();
        tags.sort_unstable();
        let want: Vec<u16> = (0..16u16).filter(|&t| t != 5).collect();
        assert_eq!(tags, want, "no loss, no duplication");
        for (pe, _) in delivered {
            assert_eq!(pe, 1 * 4 + 1);
        }
    }

    #[test]
    fn random_permutation_traffic() {
        let mut net = Network::new(8, 8);
        let n = 64;
        let mut pending = Vec::new();
        for pe in 0..n {
            let dest = (pe * 37 + 11) % n; // fixed permutation
            pending.push((pe, pkt((dest % 8) as u8, (dest / 8) as u8, pe as u16)));
        }
        let delivered = drain(&mut net, pending, n);
        assert_eq!(delivered.len(), n);
        assert!(net.is_empty());
    }

    #[test]
    fn latency_stats_accumulate() {
        let mut net = Network::new(4, 4);
        let delivered = drain(&mut net, vec![(0, pkt(2, 3, 0))], 1);
        assert_eq!(delivered.len(), 1);
        // 2 east hops + turn + 3 south hops: latency >= 5 cycles
        assert!(net.stats.max_latency >= 5, "{:?}", net.stats);
        assert_eq!(net.stats.total_latency, net.stats.max_latency);
    }

    /// Regression (latency misattribution): two structurally identical
    /// packets in flight at once must each keep their own birth cycle.
    /// The old code recovered births by `Packet` equality against the
    /// router inputs, so when the two met at the destination router the
    /// ejecting one was charged the *other's* (younger) birth.
    ///
    /// 3×3 torus, both packets addressed to (1,1) with equal payloads:
    /// * B injected at (1,2) on cycle 0 rides the Y ring and ejects on
    ///   cycle 2 — latency 2;
    /// * A injected at (0,1) on cycle 1 reaches (1,1) on cycle 2, loses
    ///   the eject port to B, deflects around the X ring, and ejects on
    ///   cycle 5 — latency 4.
    /// Total 6, max 4. The buggy scheme reported total 5 (B charged A's
    /// birth of 1).
    #[test]
    fn identical_packets_keep_their_birth_cycles() {
        let mut net = Network::new(3, 3);
        let p = pkt(1, 1, 0); // same destination, same payload for both
        let n = 9;
        let pe_a = 3; // (0,1)
        let pe_b = 7; // (1,2)

        let mut inject: Vec<Option<Packet>> = vec![None; n];
        inject[pe_b] = Some(p); // B, born cycle 0
        assert!(net.step(&inject).inject_ok[pe_b]);

        let mut inject: Vec<Option<Packet>> = vec![None; n];
        inject[pe_a] = Some(p); // A, born cycle 1
        assert!(net.step(&inject).inject_ok[pe_a]);

        let none: Vec<Option<Packet>> = vec![None; n];
        let res = net.step(&none); // cycle 2: B ejects, A deflects
        assert_eq!(res.ejected[4], Some(p), "B delivered at (1,1)");
        assert_eq!(net.stats.delivered, 1);
        assert_eq!(net.stats.total_latency, 2, "B charged its own birth");
        assert_eq!(net.stats.deflections, 1, "A deflected east");

        for _ in 0..3 {
            net.step(&none); // cycles 3-5: A circles the X ring
        }
        assert_eq!(net.stats.delivered, 2);
        assert!(net.is_empty());
        assert_eq!(net.stats.total_latency, 2 + 4);
        assert_eq!(net.stats.max_latency, 4);
    }

    #[test]
    fn one_by_one_torus_self_loop() {
        let mut net = Network::new(1, 1);
        let p = pkt(0, 0, 1);
        let delivered = drain(&mut net, vec![(0, p)], 1);
        assert_eq!(delivered, vec![(0, p)]);
    }

    #[test]
    fn in_flight_tracking() {
        let mut net = Network::new(4, 1);
        let mut inject = vec![None; 4];
        inject[0] = Some(pkt(2, 0, 0));
        let ok = net.step(&inject).inject_ok[0];
        assert!(ok);
        assert_eq!(net.in_flight(), 1);
        net.step(&vec![None; 4]);
        let got = net.step(&vec![None; 4]).ejected[2];
        // after 3 cycles: 2 hops + eject
        assert!(got.is_some());
        assert!(net.is_empty());
    }

    #[test]
    fn step_result_buffers_reset_each_cycle() {
        let mut net = Network::new(2, 2);
        let mut inject = vec![None; 4];
        inject[0] = Some(pkt(0, 0, 1)); // self delivery, cycle 0
        let r = net.step(&inject);
        assert!(r.ejected[0].is_some());
        assert_eq!(r.ejected_pes, vec![0]);
        let r = net.step(&vec![None; 4]);
        assert!(r.ejected[0].is_none(), "stale ejects must clear");
        assert!(!r.inject_ok[0]);
        assert!(r.ejected_pes.is_empty());
    }

    /// `step_sparse` with an explicit injector list is the same machine
    /// as the scanning `step`.
    #[test]
    fn sparse_step_matches_dense_step() {
        let mut dense = Network::new(4, 4);
        let mut sparse = Network::new(4, 4);
        let n = 16;
        for cycle in 0..40u64 {
            let mut inject: Vec<Option<Packet>> = vec![None; n];
            let mut injectors = Vec::new();
            if cycle < 16 && cycle % 3 != 2 {
                let pe = cycle as usize;
                inject[pe] = Some(pkt((pe as u8 * 7 + 3) % 4, (pe as u8 * 5 + 1) % 4, pe as u16));
                injectors.push(pe as u32);
            }
            let a = dense.step(&inject).clone();
            let b = sparse.step_sparse(&inject, &injectors).clone();
            assert_eq!(a.ejected, b.ejected, "cycle {cycle}");
            assert_eq!(a.inject_ok, b.inject_ok, "cycle {cycle}");
        }
        assert_eq!(dense.stats, sparse.stats);
        assert_eq!(dense.in_flight(), sparse.in_flight());
    }

    /// The precomputed topology tables are exactly the div/mod
    /// derivations they replaced.
    #[test]
    fn topology_tables_match_divmod() {
        let net = Network::new(5, 3);
        for me in 0..15usize {
            let (x, y) = (me % 5, me / 5);
            assert_eq!(net.xy[me], (x as u8, y as u8));
            assert_eq!(net.east_of[me] as usize, y * 5 + (x + 1) % 5);
            assert_eq!(net.south_of[me] as usize, ((y + 1) % 3) * 5 + x);
            assert_eq!(net.west_src[me] as usize, y * 5 + (x + 4) % 5);
            assert_eq!(net.north_src[me] as usize, ((y + 2) % 3) * 5 + x);
        }
    }

    /// Per-router activity counters: a single DOR-routed packet from
    /// (0,0) to (2,3) on a 4×4 torus switches through exactly six
    /// routers — the injection at (0,0) plus one link arrival at each of
    /// (1,0), (2,0), (2,1), (2,2) and (2,3) — with no deflections.
    #[test]
    fn router_activity_counts_hops_and_deflections() {
        let mut net = Network::new(4, 4);
        let p = pkt(2, 3, 7);
        let delivered = drain(&mut net, vec![(0, p)], 1);
        assert_eq!(delivered.len(), 1);
        let traffic = net.router_traffic();
        assert_eq!(traffic.iter().sum::<u64>(), 6);
        for (me, want) in [(0, 1), (1, 1), (2, 1), (6, 1), (10, 1), (14, 1)] {
            assert_eq!(traffic[me], want, "router {me}");
        }
        assert_eq!(net.router_deflections().iter().sum::<u64>(), 0);

        // contested eject: deflection counters land on the routers that
        // deflected and sum to the global stat
        let mut net = Network::new(3, 3);
        let mut pending = Vec::new();
        for pe in 0..9 {
            if pe != 4 {
                pending.push((pe, pkt(1, 1, pe as u16)));
            }
        }
        let delivered = drain(&mut net, pending, 8);
        assert_eq!(delivered.len(), 8);
        assert_eq!(
            net.router_deflections().iter().sum::<u64>(),
            net.stats.deflections
        );
    }

    #[test]
    fn network_stats_json_roundtrip() {
        let s = NetworkStats {
            injected: 100,
            delivered: 98,
            deflections: 7,
            inject_stalls: 3,
            total_latency: 412,
            max_latency: 19,
        };
        let j = s.to_json_value();
        let text = crate::util::json::write(&j);
        let parsed = crate::util::json::parse(&text).unwrap();
        let back = NetworkStats::from_json_value(&parsed).unwrap();
        assert_eq!(back, s);
        // strictness: unknown counters and non-integers are rejected
        let bad = crate::util::json::parse("{\"bogus\": 1}").unwrap();
        assert!(NetworkStats::from_json_value(&bad).is_err());
        let bad = crate::util::json::parse("{\"injected\": -1}").unwrap();
        assert!(NetworkStats::from_json_value(&bad).is_err());
    }
}
