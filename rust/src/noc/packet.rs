//! The 56 b Hoplite packet.
//!
//! Field layout (LSB first):
//! ```text
//!   payload   : 32 b   f32 token value
//!   dest_x    :  5 b   torus column   (overlays up to 32x32)
//!   dest_y    :  5 b   torus row
//!   local_idx : 13 b   node index in the destination PE's graph memory
//!   slot      :  1 b   operand slot (0/1)
//!   ------------------------------------------------------------------
//!   total     : 56 b   == the paper's link width
//! ```

/// Max torus dimension supported by the 5 b coordinate fields.
pub const MAX_DIM: usize = 32;
/// Max local nodes addressable by the 13 b local index.
pub const MAX_LOCAL_NODES: usize = 1 << 13;

/// One dataflow token in flight.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Packet {
    pub dest_x: u8,
    pub dest_y: u8,
    /// node index within the destination PE's local graph memory
    pub local_idx: u16,
    /// operand slot at the destination node
    pub slot: u8,
    /// token value
    pub payload: f32,
}

impl Packet {
    pub const WIDTH_BITS: u32 = 32 + 5 + 5 + 13 + 1;

    /// This header with `payload` filled in — the baked route-table
    /// inject path ([`crate::program::RuntimeTables`]): the compiled
    /// entry is the complete header, only the token value is written at
    /// inject time.
    #[inline]
    #[must_use]
    pub fn with_payload(mut self, payload: f32) -> Self {
        self.payload = payload;
        self
    }

    /// Pack to the 56 b wire format (in the low bits of a u64).
    pub fn pack56(&self) -> u64 {
        debug_assert!((self.dest_x as usize) < MAX_DIM);
        debug_assert!((self.dest_y as usize) < MAX_DIM);
        debug_assert!((self.local_idx as usize) < MAX_LOCAL_NODES);
        debug_assert!(self.slot < 2);
        let mut w = self.payload.to_bits() as u64;
        w |= (self.dest_x as u64) << 32;
        w |= (self.dest_y as u64) << 37;
        w |= (self.local_idx as u64) << 42;
        w |= (self.slot as u64) << 55;
        w
    }

    /// Unpack from the wire format.
    pub fn unpack56(w: u64) -> Self {
        Packet {
            payload: f32::from_bits((w & 0xFFFF_FFFF) as u32),
            dest_x: ((w >> 32) & 0x1F) as u8,
            dest_y: ((w >> 37) & 0x1F) as u8,
            local_idx: ((w >> 42) & 0x1FFF) as u16,
            slot: ((w >> 55) & 0x1) as u8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn width_is_56_bits() {
        assert_eq!(Packet::WIDTH_BITS, 56);
        // wire image never uses bits >= 56
        let p = Packet {
            dest_x: 31,
            dest_y: 31,
            local_idx: (MAX_LOCAL_NODES - 1) as u16,
            slot: 1,
            payload: f32::from_bits(u32::MAX),
        };
        assert_eq!(p.pack56() >> 56, 0);
    }

    #[test]
    fn roundtrip_exhaustive_fields() {
        for &x in &[0u8, 1, 15, 31] {
            for &y in &[0u8, 7, 31] {
                for &idx in &[0u16, 1, 4095, 8191] {
                    for slot in 0..2u8 {
                        let p = Packet {
                            dest_x: x,
                            dest_y: y,
                            local_idx: idx,
                            slot,
                            payload: -123.456,
                        };
                        assert_eq!(Packet::unpack56(p.pack56()), p);
                    }
                }
            }
        }
    }

    #[test]
    fn payload_bits_preserved() {
        for bits in [0u32, 1, 0x7F80_0000 /* inf */, 0xFFC0_0000 /* nan */] {
            let p = Packet {
                dest_x: 3,
                dest_y: 4,
                local_idx: 77,
                slot: 0,
                payload: f32::from_bits(bits),
            };
            let q = Packet::unpack56(p.pack56());
            assert_eq!(q.payload.to_bits(), bits);
        }
    }

    #[test]
    fn capacity_covers_paper_design_point() {
        // 16x16 overlay, thousands of local nodes per PE (paper: "a large
        // number of local nodes (thousands) per processor").
        assert!(MAX_DIM * MAX_DIM >= 256);
        assert!(MAX_LOCAL_NODES >= 4096);
    }
}
